"""Mixture-of-Experts layer: shared + routed experts, top-k routing, fixed
capacity with sort-based dispatch (memory-optimal: no (S,E,C) one-hot tensor;
scatters route tokens into per-expert buffers that XLA SPMD shards over the
``model`` mesh axis => expert parallelism with compiler-inserted all_to_alls).

DeepSeek-style fine-grained MoE: ``num_shared`` always-on experts (fused into
one dense GLU of width num_shared*d_ff_expert) + ``num_experts`` routed,
``top_k`` active. Aux load-balance loss (switch-style) returned for training.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.common.param import ParamDef
from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain


def moe_defs(cfg: ModelConfig):
    m = cfg.moe
    d, dt = cfg.d_model, cfg.param_dtype
    E, F = m.num_experts, m.d_ff_expert
    p = {
        "router": ParamDef((d, E), jnp.float32, ("embed", None), init="fan_in"),
        "w_gate": ParamDef((E, d, F), dt, ("experts", "embed", "expert_mlp"), init="fan_in"),
        "w_up": ParamDef((E, d, F), dt, ("experts", "embed", "expert_mlp"), init="fan_in"),
        "w_down": ParamDef((E, F, d), dt, ("experts", "expert_mlp", "embed"), init="fan_in"),
    }
    if m.num_shared > 0:
        FS = m.num_shared * F
        p["shared"] = {
            "w_gate": ParamDef((d, FS), dt, ("embed", "mlp"), init="fan_in"),
            "w_up": ParamDef((d, FS), dt, ("embed", "mlp"), init="fan_in"),
            "w_down": ParamDef((FS, d), dt, ("mlp", "embed"), init="fan_in"),
        }
    return p


def _glu(x, wg, wu, wd):
    h = jax.nn.silu(x @ wg) * (x @ wu)
    return h @ wd


GROUP = 1024  # tokens per dispatch group (GShard-style); groups ride the batch sharding


def apply_moe(cfg: ModelConfig, p, x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """x: (B, T, D) -> (out, aux_loss).

    GShard-style grouped one-hot dispatch: tokens are split into groups of
    GROUP (the group axis inherits the data sharding); capacity
    C = cf * GROUP * k / E per (group, expert). Dispatch/combine are einsums
    (no scatter), so XLA SPMD turns the (group-sharded) -> (expert-sharded)
    boundary into an all_to_all instead of replicating buffers.
    """
    m = cfg.moe
    B, T, D = x.shape
    S = B * T
    E, K = m.num_experts, m.top_k

    g = min(GROUP, S)
    pad = (-S) % g
    xf = x.reshape(S, D)
    if pad:
        xf = jnp.pad(xf, ((0, pad), (0, 0)))
    G = xf.shape[0] // g
    xg = xf.reshape(G, g, D)
    xg = constrain(xg, "act_batch", None, None)
    C = max(int(m.capacity_factor * g * K / E), 4)
    C = min(C, g)

    logits = xg.astype(jnp.float32) @ p["router"]          # (G, g, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, K)                 # (G, g, K)
    top_w = top_w / jnp.maximum(jnp.sum(top_w, -1, keepdims=True), 1e-9)

    # switch-style load-balance aux loss
    oh_all = jax.nn.one_hot(top_e, E, dtype=jnp.float32)   # (G, g, K, E)
    aux = E * jnp.sum(jnp.mean(jnp.sum(oh_all, 2), axis=(0, 1)) *
                      jnp.mean(probs, axis=(0, 1))) / K

    # position of each (token, choice) within its expert, FIFO over (g*K)
    ohf = oh_all.reshape(G, g * K, E)
    pos = jnp.cumsum(ohf, axis=1) - 1.0                    # (G, g*K, E)
    pos_choice = jnp.sum(pos * ohf, axis=-1).reshape(G, g, K)

    combine = jnp.zeros((G, g, E, C), jnp.float32)
    for j in range(K):
        keep = (pos_choice[:, :, j] < C)
        w = jnp.where(keep, top_w[:, :, j], 0.0)
        oh_e = oh_all[:, :, j]                             # (G, g, E)
        oh_c = jax.nn.one_hot(pos_choice[:, :, j], C, dtype=jnp.float32)
        combine = combine + (w[..., None] * oh_e)[..., None] * oh_c[:, :, None, :]
    dispatch = (combine > 0).astype(x.dtype)               # (G, g, E, C)

    ein = jnp.einsum("GgEC,Ggd->GECd", dispatch, xg)
    ein = constrain(ein, "act_batch", "act_experts", None, None)
    h = jax.nn.silu(jnp.einsum("GECd,Edf->GECf", ein, p["w_gate"]))
    h = h * jnp.einsum("GECd,Edf->GECf", ein, p["w_up"])
    h = constrain(h, "act_batch", "act_experts", None, "act_expert_mlp")
    eout = jnp.einsum("GECf,Efd->GECd", h, p["w_down"])
    out = jnp.einsum("GgEC,GECd->Ggd", combine.astype(x.dtype), eout)

    out = out.reshape(-1, D)
    if pad:
        out = out[:S]
    if m.num_shared > 0:
        sp = p["shared"]
        out = out + _glu(xf[:S] if pad else xf, sp["w_gate"], sp["w_up"], sp["w_down"])
    return out.reshape(B, T, D), aux
