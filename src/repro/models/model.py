"""Full model: embed -> prefix layers (unrolled) -> scanned blocks -> norm ->
LM head. Three entry points: forward_train / prefill / decode_step.

All functions are pure; parameters/caches are pytrees declared by
``model_defs`` (see common/param.py for how init, ShapeDtypeStructs and
PartitionSpecs all derive from the same tree).
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.common.param import abstract_tree, init_tree, spec_tree
from repro.configs.base import AttentionRuntime, CPQCfg, ModelConfig
from repro.core import kv_cache as kvc
from repro.models import transformer as tfm
from repro.serving import paged_cache as pgc
from repro.models.layers import embed_defs, embed_inputs, lm_logits, norm_defs, apply_norm


@jax.custom_jvp
def _barrier(tree):
    """Differentiable ``optimization_barrier``: some JAX versions ship no JVP
    rule for the primitive, which broke every train-path test. Primal keeps
    the barrier (the scan/LICM pinning it exists for); tangents pass through."""
    return jax.lax.optimization_barrier(tree)


@_barrier.defjvp
def _barrier_jvp(primals, tangents):
    (x,), (t,) = primals, tangents
    return _barrier(x), t


# --------------------------------------------------------------------- defs


def model_defs(cfg: ModelConfig):
    return {
        "embed": embed_defs(cfg),
        "prefix": [tfm.layer_defs(cfg, m, f) for m, f in cfg.prefix_pattern],
        "blocks": tfm.stacked_block_defs(cfg),
        "final_norm": norm_defs(cfg),
    }


def init_params(cfg: ModelConfig, key: jax.Array):
    return init_tree(model_defs(cfg), key)


def abstract_params(cfg: ModelConfig):
    return abstract_tree(model_defs(cfg))


def param_specs(cfg: ModelConfig, rules: dict, mesh_shape: dict | None = None):
    return spec_tree(model_defs(cfg), rules, mesh_shape)


def _patches(cfg: ModelConfig, params, batch: dict) -> Optional[jax.Array]:
    if cfg.input_kind != "text+patches":
        return None
    return batch["patches"].astype(cfg.param_dtype) @ params["embed"]["mm_proj"]


# -------------------------------------------------------------------- train


def forward_train(cfg: ModelConfig, params, batch: dict, remat: bool = True):
    """-> (logits (B,S,V) f32, aux_loss scalar)."""
    S = (batch["tokens"] if "tokens" in batch else batch["frames"]).shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)
    x = embed_inputs(cfg, params["embed"], batch, positions)
    patches = _patches(cfg, params, batch)
    aux = jnp.zeros((), jnp.float32)

    for kind, p in zip(cfg.prefix_pattern, params["prefix"]):
        x, a = tfm.layer_train(cfg, kind, p, x, positions, patches)
        aux = aux + a

    if cfg.num_blocks:
        def one_layer(kind):
            def f(x, p):
                return tfm.layer_train(cfg, kind, p, x, positions, patches)
            # inner remat: backward recomputes one sublayer at a time
            return jax.checkpoint(f) if remat and len(cfg.block_pattern) > 1 else f

        layer_fns = [one_layer(k) for k in cfg.block_pattern]

        def body(x, block_params):
            # pin the sliced block weights inside the loop: without this, the
            # SPMD partitioner all-gathers the WHOLE stacked (num_blocks, ...)
            # FSDP weights and LICM hoists them out of the scan (measured
            # +43GB/device on jamba train — EXPERIMENTS.md §Perf)
            block_params = _barrier(block_params)
            a_blk = jnp.zeros((), jnp.float32)
            for f, p in zip(layer_fns, block_params):
                x, a = f(x, p)
                a_blk = a_blk + a
            return x, a_blk

        if remat:
            # outer remat: scan saves only the per-block carry
            body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
        x, auxs = jax.lax.scan(body, x, tuple(params["blocks"]))
        aux = aux + jnp.sum(auxs)

    x = apply_norm(cfg, params["final_norm"], x)
    return lm_logits(cfg, params, x), aux


def loss_fn(cfg: ModelConfig, params, batch: dict, remat: bool = True,
            aux_weight: float = 0.01):
    """Next-token cross entropy (+ MoE aux). labels: (B,S) int32, -1 = pad."""
    logits, aux = forward_train(cfg, params, batch, remat)
    labels = batch["labels"]
    logp = jax.nn.log_softmax(logits[:, :-1].astype(jnp.float32), axis=-1)
    tgt = labels[:, 1:]
    ok = tgt >= 0
    nll = -jnp.take_along_axis(logp, jnp.maximum(tgt, 0)[..., None], axis=-1)[..., 0]
    loss = jnp.sum(nll * ok) / jnp.maximum(jnp.sum(ok), 1)
    return loss + aux_weight * aux, {"nll": loss, "aux": aux}


# ------------------------------------------------------------------ serving


def init_caches(cfg: ModelConfig, rt: AttentionRuntime, batch: int, n_max: int):
    """Cache pytree: prefix list + per-position stacked block caches."""
    npatch = cfg.num_patch_tokens
    prefix = [tfm.layer_cache_init(cfg, rt, k, batch, n_max, npatch)
              for k in cfg.prefix_pattern]

    def stacked(kind):
        one = tfm.layer_cache_init(cfg, rt, kind, batch, n_max, npatch)
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a, (cfg.num_blocks,) + a.shape).copy(), one)

    blocks = [stacked(k) for k in cfg.block_pattern]
    return {"prefix": prefix, "blocks": blocks}


def prefill(cfg: ModelConfig, rt: AttentionRuntime, params, batch: dict, caches,
            last_index: Optional[jax.Array] = None):
    """Process the prompt; returns (logits (B,V), caches). Logits come from
    the last position, or from ``last_index`` (shared () int32) when the
    prompt is right-padded to a jit bucket (continuous-batching admission)."""
    S = (batch["tokens"] if "tokens" in batch else batch["frames"]).shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)
    x = embed_inputs(cfg, params["embed"], batch, positions)
    patches = _patches(cfg, params, batch)

    new_prefix = []
    for kind, p, c in zip(cfg.prefix_pattern, params["prefix"], caches["prefix"]):
        x, c2 = tfm.layer_prefill(cfg, rt, kind, p, x, positions, patches, c)
        new_prefix.append(c2)

    new_blocks = caches["blocks"]
    if cfg.num_blocks:
        def body(x, inp):
            block_params, block_caches = _barrier(inp)
            outs = []
            for kind, p, c in zip(cfg.block_pattern, block_params, block_caches):
                x, c2 = tfm.layer_prefill(cfg, rt, kind, p, x, positions, patches, c)
                outs.append(c2)
            return x, tuple(outs)

        x, new_blocks = jax.lax.scan(
            body, x, (tuple(params["blocks"]), tuple(caches["blocks"])))
        new_blocks = list(new_blocks)

    if last_index is not None:
        x = jax.lax.dynamic_slice_in_dim(x, last_index, 1, axis=1)
    else:
        x = x[:, -1:]
    x = apply_norm(cfg, params["final_norm"], x)
    logits = lm_logits(cfg, params, x)[:, 0]
    return logits, {"prefix": new_prefix, "blocks": new_blocks}


def decode_step(cfg: ModelConfig, rt: AttentionRuntime, params, tokens: jax.Array,
                pos: jax.Array, caches):
    """One decode step. tokens: (B, 1) int32; pos: () int32 (next position).
    Returns (logits (B, V), caches)."""
    x = embed_inputs(cfg, params["embed"], {"tokens": tokens}, pos[None])

    new_prefix = []
    for kind, p, c in zip(cfg.prefix_pattern, params["prefix"], caches["prefix"]):
        x, c2 = tfm.layer_decode(cfg, rt, kind, p, x, pos, c)
        new_prefix.append(c2)

    new_blocks = caches["blocks"]
    if cfg.num_blocks:
        def body(x, inp):
            block_params, block_caches = _barrier(inp)
            outs = []
            for kind, p, c in zip(cfg.block_pattern, block_params, block_caches):
                x, c2 = tfm.layer_decode(cfg, rt, kind, p, x, pos, c)
                outs.append(c2)
            return x, tuple(outs)

        x, new_blocks = jax.lax.scan(
            body, x, (tuple(params["blocks"]), tuple(caches["blocks"])))
        new_blocks = list(new_blocks)

    x = apply_norm(cfg, params["final_norm"], x)
    logits = lm_logits(cfg, params, x)[:, 0]
    return logits, {"prefix": new_prefix, "blocks": new_blocks}


# -------------------------------------------------- continuous (paged) serving


def init_paged_caches(cfg: ModelConfig, rt: AttentionRuntime, serving,
                      tiered: bool = False):
    """Paged cache pytree: one shared page pool per layer; slot-indexed
    contiguous state for recurrent/xattn mixers. ``serving`` is a ServingCfg;
    ``tiered`` adds the CPQ escalation arena (watermark policy)."""
    prefix = [tfm.layer_paged_cache_init(cfg, rt, k, serving, tiered)
              for k in cfg.prefix_pattern]

    def stacked(kind):
        one = tfm.layer_paged_cache_init(cfg, rt, kind, serving, tiered)
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a, (cfg.num_blocks,) + a.shape).copy(), one)

    blocks = [stacked(k) for k in cfg.block_pattern]
    return {"prefix": prefix, "blocks": blocks}


def decode_step_rows(cfg: ModelConfig, rt: AttentionRuntime, params,
                     tokens: jax.Array, rows: pgc.RowState, caches):
    """One continuous-batching decode step: every row at its own position
    (``rows.lengths``). With ``rt.paged_kernels`` (default) the dense, CPQ,
    and X/MLA attention tiers read their arenas through the fused paged
    Pallas kernels (pages DMA'd via the block table, no logical view);
    otherwise caches are gathered through the block table in jnp.
    tokens: (B, 1) int32. Returns (logits (B, V), caches)."""
    x = embed_inputs(cfg, params["embed"], {"tokens": tokens}, rows.lengths[:, None])

    new_prefix = []
    for kind, p, c in zip(cfg.prefix_pattern, params["prefix"], caches["prefix"]):
        x, c2 = tfm.layer_decode_rows(cfg, rt, kind, p, x, rows, c)
        new_prefix.append(c2)

    new_blocks = caches["blocks"]
    if cfg.num_blocks:
        def body(x, inp):
            block_params, block_caches = _barrier(inp)
            outs = []
            for kind, p, c in zip(cfg.block_pattern, block_params, block_caches):
                x, c2 = tfm.layer_decode_rows(cfg, rt, kind, p, x, rows, c)
                outs.append(c2)
            return x, tuple(outs)

        x, new_blocks = jax.lax.scan(
            body, x, (tuple(params["blocks"]), tuple(caches["blocks"])))
        new_blocks = list(new_blocks)

    x = apply_norm(cfg, params["final_norm"], x)
    logits = lm_logits(cfg, params, x)[:, 0]
    return logits, {"prefix": new_prefix, "blocks": new_blocks}


def _chunk_forward(cfg: ModelConfig, rt: AttentionRuntime, tier: int,
                   first: bool, params, tokens: jax.Array, slot: jax.Array,
                   block_row: jax.Array, offset: jax.Array, valid: jax.Array,
                   caches):
    """Shared trunk of the chunked paged forward pass: embed ``tokens``
    (1, C) at absolute positions ``offset + i``, stream every layer's
    chunk step (writes land straight in slot ``slot``'s arena pages through
    ``block_row``; the chunk's queries attend ``[0, offset + i]`` via the
    per-query-row causal mask). Returns the pre-norm hidden states
    (1, C, D) and the updated caches — the prefill head keeps the last
    valid position's logits, the speculative verify head keeps them all."""
    C = tokens.shape[1]
    positions = offset + jnp.arange(C, dtype=jnp.int32)
    x = embed_inputs(cfg, params["embed"], {"tokens": tokens}, positions)

    new_prefix = []
    for kind, p, c in zip(cfg.prefix_pattern, params["prefix"], caches["prefix"]):
        x, c2 = tfm.layer_prefill_chunk(cfg, rt, tier, first, kind, p, x,
                                        positions, slot, block_row, offset,
                                        valid, c)
        new_prefix.append(c2)

    new_blocks = caches["blocks"]
    if cfg.num_blocks:
        def body(x, inp):
            block_params, block_caches = _barrier(inp)
            outs = []
            for kind, p, c in zip(cfg.block_pattern, block_params, block_caches):
                x, c2 = tfm.layer_prefill_chunk(cfg, rt, tier, first, kind, p,
                                                x, positions, slot, block_row,
                                                offset, valid, c)
                outs.append(c2)
            return x, tuple(outs)

        x, new_blocks = jax.lax.scan(
            body, x, (tuple(params["blocks"]), tuple(caches["blocks"])))
        new_blocks = list(new_blocks)

    return x, {"prefix": new_prefix, "blocks": new_blocks}


def prefill_chunk_rows(cfg: ModelConfig, rt: AttentionRuntime, tier: int,
                       first: bool, params, tokens: jax.Array,
                       slot: jax.Array, block_row: jax.Array,
                       offset: jax.Array, valid: jax.Array, caches):
    """One CHUNK of a chunked paged admission prefill: ``tokens`` (1, C) is
    the next slice of the prompt (padded to the static chunk size with the
    edge token), embedded at absolute positions ``offset + i`` and written
    straight into slot ``slot``'s arena pages — no contiguous scratch cache
    is ever allocated, and one compiled shape serves every prompt length
    (the per-(mode, padded-length) prefill variant zoo collapses to this
    function's (mode, first-chunk) pair). Returns (logits (1, V) of the
    chunk's LAST VALID position — meaningful on the final chunk only — and
    the updated paged caches)."""
    x, caches = _chunk_forward(cfg, rt, tier, first, params, tokens, slot,
                               block_row, offset, valid, caches)
    x = jax.lax.dynamic_slice_in_dim(x, valid - 1, 1, axis=1)
    x = apply_norm(cfg, params["final_norm"], x)
    logits = lm_logits(cfg, params, x)[:, 0]
    return logits, caches


def verify_chunk_rows(cfg: ModelConfig, rt: AttentionRuntime, tier: int,
                      first: bool, params, tokens: jax.Array,
                      slot: jax.Array, block_row: jax.Array,
                      offset: jax.Array, valid: jax.Array, caches):
    """Speculative-decoding verification chunk: the SAME chunked paged
    forward pass as ``prefill_chunk_rows`` (one weight stream, Q-chunk>1
    paged attend with the per-query-row causal mask, writes into the
    draft's scratch pages through ``block_row``), but the head keeps the
    logits of EVERY chunk position — position ``offset + i`` scores
    candidate ``i+1`` — so all k drafted tokens are verified in a single
    model invocation. Returns (logits (1, C, V), updated caches); rows at
    ``i >= valid`` are jit padding (never sampled, writes masked)."""
    x, caches = _chunk_forward(cfg, rt, tier, first, params, tokens, slot,
                               block_row, offset, valid, caches)
    x = apply_norm(cfg, params["final_norm"], x)
    logits = lm_logits(cfg, params, x)
    return logits, caches


def pack_prefill_caches(cfg: ModelConfig, rt: AttentionRuntime, paged, src,
                        block_row: jax.Array, slot: jax.Array):
    """Scatter a freshly prefilled B=1 contiguous cache pytree (``src``, from
    ``prefill``) into slot ``slot`` of the paged cache pytree (admission)."""
    def pack_layer(kind, pc, sc):
        mixer, _ = kind
        if mixer in ("attn", "mla"):
            return pgc.pack_into(rt.mode, pc, sc, block_row, slot)
        if mixer == "xattn":  # static per-request K/V, slot-indexed
            return kvc.DenseKVCache(pc.k.at[slot].set(sc.k[0]),
                                    pc.v.at[slot].set(sc.v[0]), sc.length)
        # recurrent state: all leaves are (B, ...)
        return jax.tree.map(lambda d, s: d.at[slot].set(s[0]), pc, sc)

    prefix = [pack_layer(k, pc, sc)
              for k, pc, sc in zip(cfg.prefix_pattern, paged["prefix"], src["prefix"])]
    blocks = [jax.vmap(lambda c, s, kind=kind: pack_layer(kind, c, s))(pc, sc)
              for kind, pc, sc in zip(cfg.block_pattern, paged["blocks"], src["blocks"])]
    return {"prefix": prefix, "blocks": blocks}


def defrag_caches(cfg: ModelConfig, rt: AttentionRuntime, caches,
                  perm: jax.Array):
    """Apply a scheduler defrag permutation (``Scheduler.plan_defrag``) to
    every attention layer's BASE-arena page pools: mapped pages move onto
    the lowest physical ids so each request's pages become physically
    contiguous again (locality for the fused kernels' sequential reads).
    Non-attention layer state is slot-indexed, not paged."""
    def one(kind, c):
        mixer, _ = kind
        if mixer not in ("attn", "mla"):
            return c
        return pgc.permute_pool(c, perm)

    prefix = [one(k, c) for k, c in zip(cfg.prefix_pattern, caches["prefix"])]
    blocks = [jax.vmap(lambda c, kind=kind: one(kind, c))(pc)
              for kind, pc in zip(cfg.block_pattern, caches["blocks"])]
    return {"prefix": prefix, "blocks": blocks}


def copy_page_caches(cfg: ModelConfig, rt: AttentionRuntime, caches,
                     src: jax.Array, dst: jax.Array):
    """Copy physical page ``src -> dst`` in every attention layer's BASE
    arena pools — the copy-on-write split behind prefix sharing: before a
    request's first write into a page it still shares, the scheduler remaps
    its block-table entry to a fresh page and this op duplicates the payload
    (tiered arenas copy the dense arm only; non-attention layer state is
    slot-indexed, not paged)."""
    def one(kind, c):
        mixer, _ = kind
        if mixer not in ("attn", "mla"):
            return c
        return pgc.copy_page(c, src, dst)

    prefix = [one(k, c) for k, c in zip(cfg.prefix_pattern, caches["prefix"])]
    blocks = [jax.vmap(lambda c, kind=kind: one(kind, c))(pc)
              for kind, pc in zip(cfg.block_pattern, caches["blocks"])]
    return {"prefix": prefix, "blocks": blocks}


def escalate_slot(cfg: ModelConfig, rt: AttentionRuntime, caches,
                  dense_row: jax.Array, cpq_row: jax.Array, slot: jax.Array,
                  length: jax.Array):
    """Watermark-policy tier escalation: re-compress slot ``slot``'s dense K/V
    into the CPQ arena across every tiered attention layer (the paper's
    "dynamically compress" lever, applied mid-request). The host frees the
    dense pages afterwards; ``dense_row`` is the slot's pre-escalation dense
    block row, ``cpq_row`` its freshly allocated CPQ block row."""
    cpq_cfg = rt.cpq or CPQCfg()

    def esc_layer(kind, c):
        mixer, _ = kind
        if mixer != "attn" or not isinstance(c, pgc.TieredPagedCache):
            return c
        src = pgc.compress_dense_slot(
            pgc.gather_pages(c.dense.k, dense_row[None]),
            pgc.gather_pages(c.dense.v, dense_row[None]), length, cpq_cfg)
        return c._replace(cpq=pgc.pack_cpq(c.cpq, src, cpq_row, slot))

    prefix = [esc_layer(k, c) for k, c in zip(cfg.prefix_pattern, caches["prefix"])]
    blocks = [jax.vmap(lambda c, kind=kind: esc_layer(kind, c))(pc)
              for kind, pc in zip(cfg.block_pattern, caches["blocks"])]
    return {"prefix": prefix, "blocks": blocks}
