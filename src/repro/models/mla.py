"""Multi-head Latent Attention (DeepSeek-V2) — implemented as an instance of
the paper's T1 matrix decomposition.

MLA caches a learned 512-d latent ``c_kv = Norm(X W_DKV)`` (plus one shared
64-d roped key) instead of per-head K/V. Decode uses the ABSORBED form:

    score_h = (q_nope_h W_UK_h^T) c^T + q_rope k_rope^T
    out_h   = (S c) W_UV_h

which is literally ``(Q W_K^T) X^T`` / ``(S X) W_V`` with X replaced by the
learned latent — i.e. the paper's decomposition with a compressed operand.
Both stages reuse one cached c read; the roped slice is the decoupled cache.
We therefore route MLA decode through ``core.decomposed_attention`` and reuse
the XCache container (x := c_kv, KV_r := 1 shared rope head).

Modes: "decomposed" (native, default for MLA regardless of the global mode)
and "cpq" (T2 on the latent cache via CPQXCache).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.common.param import ParamDef
from repro.configs.base import AttentionRuntime, ModelConfig
from repro.core import attention as core_attn
from repro.core import cpq as cpq_lib
from repro.core import kv_cache as kvc
from repro.core.decomposed_attention import decomposed_attention
from repro.core.flash_ref import attention_auto
from repro.distributed.sharding import constrain
from repro.models.layers import apply_rope, apply_rope_rows, rope_tables


def _dims(cfg: ModelConfig):
    m = cfg.mla
    return m.kv_lora_rank, m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim


def mla_defs(cfg: ModelConfig):
    d, H = cfg.d_model, cfg.num_heads
    L, Dn, Dr, Dv = _dims(cfg)
    dt = cfg.param_dtype
    return {
        "wq": ParamDef((d, H * (Dn + Dr)), dt, ("embed", "heads"), init="fan_in"),
        "wdkv": ParamDef((d, L + Dr), dt, ("embed", None), init="fan_in"),
        "kv_norm": ParamDef((L,), jnp.float32, (None,), init="ones"),
        "wuk": ParamDef((L, H, Dn), dt, (None, "heads", None), init="fan_in"),
        "wuv": ParamDef((L, H, Dv), dt, (None, "heads", None), init="fan_in"),
        "wo": ParamDef((H * Dv, d), dt, ("heads", "embed"), init="fan_in"),
    }


def _rms(x, scale, eps=1e-6):
    xf = x.astype(jnp.float32)
    return (xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps) * scale).astype(x.dtype)


def _q_ckv(cfg: ModelConfig, p, x: jax.Array, positions: jax.Array):
    """Shared projection work: roped q (nope+rope split) and the latent."""
    B, T, _ = x.shape
    H = cfg.num_heads
    L, Dn, Dr, Dv = _dims(cfg)
    q = (x @ p["wq"]).reshape(B, T, H, Dn + Dr)
    q = constrain(q, "act_batch", None, "act_heads", None)
    q_nope, q_rope = q[..., :Dn], q[..., Dn:]
    kv = x @ p["wdkv"]
    c = _rms(kv[..., :L], p["kv_norm"])
    k_rope = kv[..., None, L:]  # (B, T, 1, Dr) shared across heads
    cos, sin = rope_tables(positions, Dr, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)
    k_rope = apply_rope(k_rope, cos, sin)
    return q_nope, q_rope, c, k_rope


def _scale(cfg: ModelConfig) -> float:
    _, Dn, Dr, _ = _dims(cfg)
    return (Dn + Dr) ** -0.5


def _out(cfg: ModelConfig, p, o: jax.Array) -> jax.Array:
    B, T = o.shape[:2]
    y = o.reshape(B, T, -1) @ p["wo"]
    return constrain(y, "act_batch", None, None)


# -------------------------------------------------------------------- train


def mla_train(cfg: ModelConfig, p, x: jax.Array, positions: jax.Array) -> jax.Array:
    """Naive (non-absorbed) path: materialize per-head K/V — best for large-T
    prefill/train where the N*H*Dn score math beats the absorbed extra FLOPs."""
    B, T, _ = x.shape
    H = cfg.num_heads
    q_nope, q_rope, c, k_rope = _q_ckv(cfg, p, x, positions)
    k_nope = jnp.einsum("btl,lhd->bthd", c, p["wuk"])
    v = jnp.einsum("btl,lhd->bthd", c, p["wuv"])
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (*k_rope.shape[:2], H, k_rope.shape[-1]))],
                        axis=-1)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    o = attention_auto(q, k, v, _scale(cfg), causal=True)
    return _out(cfg, p, o)


# ------------------------------------------------------------------ serving


def init_mla_cache(cfg: ModelConfig, rt: AttentionRuntime, batch: int, n_max: int):
    L, _, Dr, _ = _dims(cfg)
    if rt.mode == "cpq":
        return kvc.init_cpq_x(batch, n_max, L, 1, Dr, rt.cpq, cfg.param_dtype)
    return kvc.init_x(batch, n_max, L, 1, Dr, cfg.param_dtype)


def mla_prefill(cfg: ModelConfig, rt: AttentionRuntime, p, x: jax.Array,
                positions: jax.Array, cache):
    B, T, _ = x.shape
    H = cfg.num_heads
    q_nope, q_rope, c, k_rope = _q_ckv(cfg, p, x, positions)
    k_nope = jnp.einsum("btl,lhd->bthd", c, p["wuk"])
    v = jnp.einsum("btl,lhd->bthd", c, p["wuv"])
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (*k_rope.shape[:2], H, k_rope.shape[-1]))], axis=-1)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    o = attention_auto(q, k, v, _scale(cfg), causal=True)

    length = jnp.asarray(T, jnp.int32)
    if isinstance(cache, kvc.CPQXCache):
        xt = cpq_lib.cpq_compress_prefill(c[:, :, None, :], rt.cpq, cache.x.n_max)
        cache = kvc.CPQXCache(xt, kvc.append_tokens(cache.k_rope, k_rope, 0), length)
    else:
        cache = kvc.XCache(kvc.append_tokens(cache.x, c, 0),
                           kvc.append_tokens(cache.k_rope, k_rope, 0), length)
    return _out(cfg, p, o), cache


def mla_decode(cfg: ModelConfig, rt: AttentionRuntime, p, x_t: jax.Array,
               pos: jax.Array, cache):
    """Absorbed decode — the paper's decomposition over the latent cache."""
    q_nope, q_rope, c_t, k_rope_t = _q_ckv(cfg, p, x_t, pos[None])
    slot = cache.length
    new_len = cache.length + 1

    if isinstance(cache, kvc.CPQXCache):
        xt = cpq_lib.cpq_append_decode(cache.x, c_t[:, :, None, :], slot, rt.cpq)
        cache = kvc.CPQXCache(xt, kvc.append_tokens(cache.k_rope, k_rope_t, slot), new_len)
        c_arena = cpq_lib.cpq_dequant(xt, x_t.dtype)[:, :, 0, :]  # fused in kernel path
    else:
        cache = kvc.XCache(kvc.append_tokens(cache.x, c_t, slot),
                           kvc.append_tokens(cache.k_rope, k_rope_t, slot), new_len)
        c_arena = cache.x

    o = decomposed_attention(
        q_nope, q_rope, c_arena, cache.k_rope,
        w_k_nope=p["wuk"], w_v=p["wuv"], length=new_len, scale=_scale(cfg))
    return _out(cfg, p, o), cache


def init_paged_mla_cache(cfg: ModelConfig, rt: AttentionRuntime, serving):
    """Paged latent arena: X pages hold c_kv, k_rope pages the shared roped
    head (serving/paged_cache.py)."""
    from repro.serving import paged_cache as pgc

    L, _, Dr, _ = _dims(cfg)
    if rt.mode == "cpq":
        return pgc.init_paged_cpq_x(serving.num_pages, serving.page_size,
                                    serving.num_slots, L, 1, Dr, rt.cpq,
                                    cfg.param_dtype)
    return pgc.init_paged_x(serving.num_pages, serving.page_size, L, 1, Dr,
                            cfg.param_dtype)


def mla_prefill_chunk(cfg: ModelConfig, rt: AttentionRuntime, tier: int,
                      first: bool, p, x: jax.Array, positions: jax.Array,
                      slot, block_row, offset, valid, cache):
    """Chunked paged prefill over the latent arena: the chunk's c_kv (+shared
    roped key) goes straight into slot ``slot``'s X pages and its queries run
    the ABSORBED decomposition over the slot's pages — algebraically the
    one-shot prefill's dense math re-associated, so chunked admission is
    token-exact vs one-shot at f32. The CPQ latent tier compresses each chunk
    incrementally (level-0 fit on the first chunk, HQE extension after)."""
    from repro.serving import paged_cache as pgc

    q_nope, q_rope, c, k_rope = _q_ckv(cfg, p, x, positions)
    if isinstance(cache, pgc.PagedCPQXCache):
        cache = pgc.PagedCPQXCache(
            x=pgc.chunk_cpq_tensor(cache.x, slot, block_row, offset, valid,
                                   c[:, :, None, :], rt.cpq, first),
            k_rope=pgc.write_chunk_pages(cache.k_rope, block_row, offset,
                                         valid, k_rope[0]))
        o = pgc.decomposed_cpq_chunk_prefill(
            q_nope, q_rope, cache.x, cache.k_rope, block_row, slot, c,
            k_rope, offset, valid, p["wuk"], p["wuv"], _scale(cfg))
        return _out(cfg, p, o), cache

    if getattr(rt, "mesh", None) is not None:
        # mesh-native: query heads + W_UK/W_UV shard over "model"; the
        # latent pages are storage-sharded on their feature axis and
        # reassembled locally inside the shard_map (serving/sharded.py)
        from repro.serving.sharded import chunk_attend_sharded

        o, cache = chunk_attend_sharded(
            rt, cache, tier=tier, first=first, slot=slot, block_row=block_row,
            offset=offset, valid=valid, q=None, k_c=None, v_c=None, x_c=c,
            k_rope_c=k_rope, q_nope=q_nope, q_rope=q_rope,
            w_k_nope=p["wuk"], w_v=p["wuv"], scale=_scale(cfg))
        return _out(cfg, p, o), cache

    cache = pgc.PagedXCache(
        x=pgc.write_chunk_pages(cache.x, block_row, offset, valid, c[0]),
        k_rope=pgc.write_chunk_pages(cache.k_rope, block_row, offset, valid,
                                     k_rope[0]))
    if rt.paged_kernels:
        from repro.kernels.decomposed_attn.ops import paged_decomposed_prefill_tpu

        o = paged_decomposed_prefill_tpu(
            q_nope, q_rope, cache.x, cache.k_rope, block_row, offset, valid,
            p["wuk"], p["wuv"], _scale(cfg))
    else:
        o = decomposed_attention(
            q_nope, q_rope, pgc.gather_pages(cache.x, block_row[None]),
            pgc.gather_pages(cache.k_rope, block_row[None]),
            w_k_nope=p["wuk"], w_v=p["wuv"], length=offset + valid,
            scale=_scale(cfg),
            query_positions=offset + jnp.arange(x.shape[1], dtype=jnp.int32))
    return _out(cfg, p, o), cache


def _q_ckv_rows(cfg: ModelConfig, p, x_t: jax.Array, positions: jax.Array):
    """Per-row-position variant of _q_ckv for one-token continuous decode."""
    B, T, _ = x_t.shape
    H = cfg.num_heads
    L, Dn, Dr, Dv = _dims(cfg)
    q = (x_t @ p["wq"]).reshape(B, T, H, Dn + Dr)
    q = constrain(q, "act_batch", None, "act_heads", None)
    q_nope, q_rope = q[..., :Dn], q[..., Dn:]
    kv = x_t @ p["wdkv"]
    c = _rms(kv[..., :L], p["kv_norm"])
    k_rope = kv[..., None, L:]  # (B, 1, 1, Dr)
    cos, sin = rope_tables(positions, Dr, cfg.rope_theta)  # (B, Dr/2)
    return q_nope, apply_rope_rows(q_rope, cos, sin), c, \
        apply_rope_rows(k_rope, cos, sin)


def mla_decode_rows(cfg: ModelConfig, rt: AttentionRuntime, p, x_t: jax.Array,
                    rows, cache):
    """Absorbed decode over a paged latent arena with per-row positions.
    With ``rt.paged_kernels`` the latent (X) tier runs the fused paged
    decomposed kernel — latent pages are DMA'd straight from the arena
    through the block table, no logical view. The CPQ-compressed latent
    keeps the dequant-gather path."""
    from repro.kernels.decomposed_attn.ops import paged_decomposed_decode_tpu
    from repro.serving import paged_cache as pgc

    q_nope, q_rope, c_t, k_rope_t = _q_ckv_rows(cfg, p, x_t, rows.lengths)
    new_len = rows.lengths + rows.active.astype(jnp.int32)

    if getattr(rt, "mesh", None) is not None and isinstance(cache, pgc.PagedXCache):
        # mesh-native absorbed decode: head-sharded shard_map over the
        # storage-sharded latent arena (serving/sharded.py)
        from repro.serving.sharded import decode_attend_sharded

        o, cache = decode_attend_sharded(
            rt, cache, rows, q=None, k_t=None, v_t=None, x_t=c_t,
            k_rope_t=k_rope_t, q_nope=q_nope, q_rope=q_rope,
            w_k_nope=p["wuk"], w_v=p["wuv"], scale=_scale(cfg))
        return _out(cfg, p, o), cache

    if isinstance(cache, pgc.PagedCPQXCache):
        cache = pgc.PagedCPQXCache(
            x=pgc.append_cpq_tensor(cache.x, rows, c_t[:, :, None, :], rt.cpq),
            k_rope=pgc.write_token_pages(cache.k_rope, rows.block_table,
                                         rows.lengths, rows.active, k_rope_t[:, 0]))
        xt = pgc.logical_cpq(cache.x, rows.block_table)
        c_arena = cpq_lib.cpq_dequant(xt, x_t.dtype)[:, :, 0, :]
    else:
        cache = pgc.append_x(cache, rows, c_t, k_rope_t)
        if rt.paged_kernels:
            o = paged_decomposed_decode_tpu(
                q_nope, q_rope, cache.x, cache.k_rope, rows.block_table,
                new_len, p["wuk"], p["wuv"], _scale(cfg))
            return _out(cfg, p, o), cache
        c_arena = pgc.gather_pages(cache.x, rows.block_table)

    o = decomposed_attention(
        q_nope, q_rope, c_arena, pgc.gather_pages(cache.k_rope, rows.block_table),
        w_k_nope=p["wuk"], w_v=p["wuv"], length=new_len, scale=_scale(cfg))
    return _out(cfg, p, o), cache
