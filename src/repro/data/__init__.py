from repro.data.pipeline import DataConfig, SyntheticLMData, make_batch_specs  # noqa: F401
