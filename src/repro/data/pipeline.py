"""Deterministic, stateless-seekable synthetic LM data pipeline.

Fault-tolerance contract: batch(step) is a pure function of (seed, step, data
config) — no iterator state to checkpoint. After a restart, resuming from
step k replays exactly the batches k, k+1, ... on any mesh shape (elastic).
A background thread prefetches ``prefetch`` steps ahead.

The token stream is a Zipf-ish categorical over the vocab with a repeating
n-gram structure so that next-token loss is learnable (the train_100m example
drives loss visibly down) — better than uniform noise for validating
end-to-end training, while requiring no external corpus (everything offline).
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator, Optional

import numpy as np

from repro.configs.base import ModelConfig, ShapeCfg


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    ngram: int = 8          # repeated-structure period (learnability)
    zipf_a: float = 1.2     # token frequency skew
    prefetch: int = 2


class SyntheticLMData:
    def __init__(self, cfg: ModelConfig, shape: ShapeCfg, dcfg: DataConfig = DataConfig()):
        self.cfg, self.shape, self.dcfg = cfg, shape, dcfg
        v = cfg.vocab_size
        rng = np.random.default_rng(dcfg.seed)
        # fixed Zipf-ish unigram table + deterministic bigram successor table:
        # token t is followed by succ[t] with prob .6, else unigram sample
        p = 1.0 / np.arange(1, v + 1) ** dcfg.zipf_a
        self._p = (p / p.sum()).astype(np.float64)
        self._succ = rng.permutation(v).astype(np.int64)

    def batch(self, step: int) -> dict:
        """Pure function of step -> {'tokens','labels'[, 'frames'|'patches']}."""
        cfg, shape = self.cfg, self.shape
        B, S = shape.global_batch, shape.seq_len
        rng = np.random.default_rng((self.dcfg.seed, step))
        v = cfg.vocab_size
        base = rng.choice(v, size=(B, S), p=self._p)
        follow = rng.random((B, S)) < 0.6
        toks = base.copy()
        for t in range(1, S):
            toks[:, t] = np.where(follow[:, t], self._succ[toks[:, t - 1]], base[:, t])
        out = {"tokens": toks.astype(np.int32), "labels": toks.astype(np.int32)}
        if cfg.input_kind == "audio_frames":
            # EnCodec frontend stub: frame embedding = code-conditioned noise
            emb = rng.standard_normal((v, 8)).astype(np.float32)
            proj = rng.standard_normal((8, cfg.d_model)).astype(np.float32) * 0.1
            out["frames"] = (emb[toks] @ proj).astype(np.float32)
            del out["tokens"]
        if cfg.input_kind == "text+patches":
            out["patches"] = rng.standard_normal(
                (B, cfg.num_patch_tokens, cfg.d_model)).astype(np.float32) * 0.02
        return out

    def __iter__(self) -> Iterator[dict]:
        return self.iter_from(0)

    def iter_from(self, step: int) -> Iterator[dict]:
        """Prefetching iterator starting at ``step`` (resume point)."""
        q: queue.Queue = queue.Queue(maxsize=max(self.dcfg.prefetch, 1))
        stop = threading.Event()

        def producer():
            s = step
            while not stop.is_set():
                try:
                    q.put(self.batch(s), timeout=0.5)
                    s += 1
                except queue.Full:
                    continue

        th = threading.Thread(target=producer, daemon=True)
        th.start()
        try:
            while True:
                yield q.get()
        finally:
            stop.set()


def make_batch_specs(cfg: ModelConfig, batch: dict, batch_axes: tuple):
    """PartitionSpecs matching a concrete batch dict."""
    from jax.sharding import PartitionSpec as P
    b = batch_axes if batch_axes else None
    out = {}
    for k, a in batch.items():
        out[k] = P(b, *([None] * (a.ndim - 1)))
    return out
