"""ShapeDtypeStruct stand-ins + PartitionSpecs for every model input, per
(arch x shape x mesh). No device allocation — the dry-run lowers against
these directly (the shannon/kernels pattern).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import AttentionRuntime, ModelConfig, ShapeCfg
from repro.distributed.cache_specs import cache_pspecs
from repro.distributed.rules import batch_axes
from repro.distributed.sharding import fit_spec_to_shape
from repro.models import model as M

SDS = jax.ShapeDtypeStruct


def _fit(specs, abstract, mesh):
    """Drop spec axes that don't divide the concrete dims (e.g. 4 heads / 16)."""
    return jax.tree.map(
        lambda s, a: fit_spec_to_shape(s, a.shape, mesh), specs, abstract,
        is_leaf=lambda x: isinstance(x, P))


def _batch_ax(shape: ShapeCfg, mesh) -> tuple:
    ms = dict(zip(mesh.axis_names, mesh.devices.shape))
    return batch_axes("pod" in mesh.axis_names, shape.global_batch, ms)


def _seq_ax(shape: ShapeCfg, mesh, b_ax: tuple) -> tuple:
    """Token-arena sharding for decode caches: use the axes the batch left
    free (long-context batch=1 shards the sequence instead)."""
    if shape.kind != "decode":
        return ()
    free = tuple(a for a in ("data",) if a not in b_ax)
    return free


def train_inputs(cfg: ModelConfig, shape: ShapeCfg, mesh):
    """-> (SDS tree, PartitionSpec tree) for the train batch."""
    B, S = shape.global_batch, shape.seq_len
    b = _batch_ax(shape, mesh)
    bspec = P(b if len(b) > 1 else (b[0] if b else None))
    batch = {"labels": SDS((B, S), jnp.int32)}
    specs = {"labels": bspec}
    if cfg.input_kind == "audio_frames":
        batch["frames"] = SDS((B, S, cfg.d_model), jnp.bfloat16)
        specs["frames"] = P(bspec[0], None, None)
    else:
        batch["tokens"] = SDS((B, S), jnp.int32)
        specs["tokens"] = bspec
    if cfg.input_kind == "text+patches":
        batch["patches"] = SDS((B, cfg.num_patch_tokens, cfg.d_model), jnp.bfloat16)
        specs["patches"] = P(bspec[0], None, None)
    return batch, specs


def prefill_inputs(cfg: ModelConfig, rt: AttentionRuntime, shape: ShapeCfg, mesh):
    """-> (batch SDS, batch specs, caches SDS, cache specs)."""
    B, S = shape.global_batch, shape.seq_len
    batch, specs = train_inputs(cfg, shape, mesh)
    del batch["labels"], specs["labels"]
    b = _batch_ax(shape, mesh)
    s = _seq_ax(shape, mesh, b)
    caches = jax.eval_shape(partial(M.init_caches, cfg, rt, B, S))
    cspecs = _fit(cache_pspecs(cfg, rt, b if b else None, s if s else None),
                  caches, mesh)
    return batch, specs, caches, cspecs


def decode_inputs(cfg: ModelConfig, rt: AttentionRuntime, shape: ShapeCfg, mesh):
    """-> (tokens SDS, tokens spec, pos SDS, caches SDS, cache specs).

    decode_* shapes lower ``serve_step``: one new token against a cache of
    seq_len tokens (arena seq_len + headroom)."""
    B, N = shape.global_batch, shape.seq_len
    b = _batch_ax(shape, mesh)
    s = _seq_ax(shape, mesh, b)
    tokens = SDS((B, 1), jnp.int32)
    tspec = P(b if len(b) > 1 else (b[0] if b else None), None)
    pos = SDS((), jnp.int32)
    caches = jax.eval_shape(partial(M.init_caches, cfg, rt, B, N))
    cspecs = _fit(cache_pspecs(cfg, rt, b if b else None, s if s else None),
                  caches, mesh)
    return tokens, tspec, pos, caches, cspecs
