"""Training driver.

Smoke scale (this CPU container):
  PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b --smoke \
      --steps 50 --batch 8 --seq 128

Production scale (TPU pod): same entry point with --mesh single|multi — the
step is pjit-ed with the FSDP+TP specs from distributed/rules.py.

Fault tolerance: auto-resume from the newest complete checkpoint; async
sharded checkpoints every --ckpt-every steps; the data pipeline is stateless-
seekable so a restart replays the exact batch sequence; metrics stream to
<ckpt>/metrics.jsonl (heartbeat for external watchdogs / straggler monitors).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time
from functools import partial
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import SHAPES, get_config, smoke_config
from repro.configs.base import ShapeCfg
from repro.data import DataConfig, SyntheticLMData
from repro.distributed.rules import act_rules, batch_axes, param_rules
from repro.distributed.sharding import sharding_context
from repro.launch.mesh import make_production_mesh, mesh_shape_dict
from repro.models import model as M
from repro.optim import adafactor, adamw, cosine_schedule
from repro.train.step import TrainStepCfg, make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--optimizer", choices=["adamw", "adafactor"], default="adamw")
    ap.add_argument("--mesh", choices=["none", "single", "multi"], default="none")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--remat", action="store_true", default=False)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_config(cfg)
    shape = ShapeCfg("train", args.seq, args.batch, "train")

    lr = cosine_schedule(args.lr, args.warmup, args.steps)
    opt = adamw(lr) if args.optimizer == "adamw" else adafactor(lr)
    tstep = make_train_step(cfg, opt, TrainStepCfg(
        microbatches=args.microbatches, remat=args.remat))

    key = jax.random.PRNGKey(args.seed)
    data = SyntheticLMData(cfg, shape, DataConfig(seed=args.seed))

    mesh = None
    if args.mesh != "none":
        mesh = make_production_mesh(multi_pod=args.mesh == "multi")

    if mesh is None:
        params = M.init_params(cfg, key)
        opt_state = opt.init(params)
        step_fn = jax.jit(tstep, donate_argnums=(0, 1))
        put = lambda b: jax.tree.map(jnp.asarray, b)  # noqa: E731
    else:
        from jax.sharding import NamedSharding, PartitionSpec as P

        prules = param_rules(args.mesh == "multi")
        pspecs = M.param_specs(cfg, prules, mesh_shape_dict(mesh))
        abstract = M.abstract_params(cfg)
        named = lambda t: jax.tree.map(  # noqa: E731
            lambda s: NamedSharding(mesh, s), t, is_leaf=lambda x: isinstance(x, P))
        ospecs = opt.state_specs(pspecs, abstract)
        b_ax = batch_axes(args.mesh == "multi", args.batch, mesh_shape_dict(mesh))
        bspec = P(b_ax if len(b_ax) > 1 else (b_ax[0] if b_ax else None))
        with sharding_context(mesh, act_rules(args.mesh == "multi")):
            params = jax.jit(partial(M.init_params, cfg),
                             out_shardings=named(pspecs))(key)
            opt_state = jax.jit(opt.init, out_shardings=named(ospecs))(params)
            step_fn = jax.jit(tstep, donate_argnums=(0, 1),
                              in_shardings=(named(pspecs), named(ospecs), None, None),
                              out_shardings=(named(pspecs), named(ospecs), None))
        put = lambda b: jax.tree.map(  # noqa: E731
            lambda x: jax.device_put(x, NamedSharding(
                mesh, P(bspec[0], *([None] * (x.ndim - 1))))), b)

    ckpt = CheckpointManager(args.ckpt_dir, keep=3) if args.ckpt_dir else None
    start = 0
    if ckpt and ckpt.latest_step() is not None:
        start = ckpt.latest_step()
        state = ckpt.restore(start, {"params": params, "opt": opt_state})
        params, opt_state = state["params"], state["opt"]
        print(f"[train] resumed from step {start}")

    log_path = Path(args.ckpt_dir) / "metrics.jsonl" if args.ckpt_dir else None
    it = data.iter_from(start)
    t0 = time.time()
    ctx = sharding_context(mesh, act_rules(args.mesh == "multi")) if mesh else None
    if ctx:
        ctx.__enter__()
    try:
        for step in range(start, args.steps):
            batch = put(next(it))
            params, opt_state, metrics = step_fn(
                params, opt_state, jnp.asarray(step, jnp.int32), batch)
            if (step + 1) % args.log_every == 0 or step == start:
                m = {k: float(v) for k, v in metrics.items()}
                dt = time.time() - t0
                rec = {"step": step + 1, **m, "elapsed_s": round(dt, 2)}
                print(f"[train] {rec}")
                if log_path:
                    with open(log_path, "a") as f:
                        f.write(json.dumps(rec) + "\n")
            if ckpt and (step + 1) % args.ckpt_every == 0:
                ckpt.save_async(step + 1, {"params": params, "opt": opt_state})
        if ckpt:
            ckpt.save(args.steps, {"params": params, "opt": opt_state})
    finally:
        if ctx:
            ctx.__exit__(None, None, None)
    final_loss = float(metrics["loss"])
    print(f"[train] done: {args.steps} steps, final loss {final_loss:.4f}")
    return final_loss


if __name__ == "__main__":
    main()
