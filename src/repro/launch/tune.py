"""Serving auto-tuner CLI — evolutionary Pareto search over ``ServingCfg``.

  PYTHONPATH=src python -m launch.tune --budget 24 --seed 0 --smoke

Runs the seeded μ+λ search (``repro.tuning``) against the real
``ContinuousServeEngine`` on a fixed seeded mixed-SLO-class trace (smoke
model: CPU-runnable), prints the non-dominated frontier, and materializes
it into named presets (``latency`` / ``throughput`` / ``energy`` /
``default``) at ``--out`` (default: the packaged
``src/repro/configs/serving_presets.json`` that ``ServingCfg.from_preset``
and ``launch/serve.py --preset`` load).

``--smoke`` additionally asserts the acceptance contract: the frontier is
non-dominated with >= 2 distinct points, every named preset is no worse
than the hand-tuned default on its own objective axis, and a second
same-seed search (evaluations memoized from the first — the loop logic
re-runs, the engine does not) reproduces the identical frontier.

``--checkpoint PATH`` saves the evaluated points + RNG state after every
evaluation; re-running with the same arguments resumes bit-identically.
"""
from __future__ import annotations

import argparse
import sys
import time


def _fmt_genome(genome: dict) -> str:
    return " ".join(f"{k}={v:g}" if isinstance(v, float) else f"{k}={v}"
                    for k, v in genome.items())


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="evolutionary Pareto auto-tuner for the serving config")
    ap.add_argument("--budget", type=int, default=24,
                    help="total engine evaluations (default 24)")
    ap.add_argument("--seed", type=int, default=0,
                    help="search seed: proposals AND the trace derive from "
                         "it; same seed => identical frontier")
    ap.add_argument("--smoke", action="store_true",
                    help="assert the acceptance contract (non-dominated "
                         "frontier, >= 2 distinct points, presets no worse "
                         "than the hand-tuned default on their own axis, "
                         "same-seed reproducibility)")
    ap.add_argument("--arch", default="qwen1.5-0.5b",
                    help="architecture searched (always smoke-sized: the "
                         "tuner measures SCHEDULING, and the energy axis "
                         "prices it at paper scale)")
    ap.add_argument("--requests", type=int, default=12,
                    help="trace length in requests (default 12)")
    ap.add_argument("--rate", type=float, default=2.0,
                    help="trace mean arrival rate, requests per tick")
    ap.add_argument("--trace", default="slo", choices=["slo", "mixed"],
                    help="workload: 'slo' = mixed interactive/batch classes "
                         "(per-class tail objectives), 'mixed' = plain "
                         "Poisson heavy-tailed")
    ap.add_argument("--mu", type=int, default=6,
                    help="parent population size (default 6)")
    ap.add_argument("--lam", type=int, default=6,
                    help="offspring per generation (default 6)")
    ap.add_argument("--mutate-p", type=float, default=0.35)
    ap.add_argument("--crossover-p", type=float, default=0.5)
    ap.add_argument("--checkpoint", default=None, metavar="PATH",
                    help="JSON checkpoint of evaluated points + RNG state, "
                         "written after every evaluation; an existing file "
                         "is resumed bit-identically")
    ap.add_argument("--out", default=None, metavar="PATH",
                    help="presets JSON output (default: the packaged "
                         "src/repro/configs/serving_presets.json)")
    args = ap.parse_args(argv)
    if args.budget < 1:
        ap.error("--budget must be >= 1")

    import jax

    from repro.configs import ARCHS, ServingCfg, smoke_config
    from repro.models import model as M
    from repro.tuning import (ParetoSearch, ServingObjective, TraceSpec,
                              materialize, pareto_front, write_presets)

    t0 = time.time()
    cfg = smoke_config(ARCHS[args.arch])
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    trace = TraceSpec(kind=args.trace, seed=args.seed,
                      n_requests=args.requests, rate=args.rate)
    objective = ServingObjective(cfg, params, trace)
    space = objective.space

    evals = {"n": 0}

    def evaluate(genome):
        objectives, metrics = objective(genome)
        evals["n"] += 1
        print(f"[tune] eval {evals['n']:>3}  "
              f"obj=({objectives[0]:.3f}, {objectives[1]:.2f}, "
              f"{objectives[2]:.3f})  {_fmt_genome(genome)}")
        return objectives, metrics

    search = ParetoSearch(space, evaluate, seed=args.seed, mu=args.mu,
                          lam=args.lam, mutate_p=args.mutate_p,
                          crossover_p=args.crossover_p,
                          checkpoint=args.checkpoint)
    resumed = len(search.records)
    if resumed:
        print(f"[tune] resumed {resumed} evaluated points from "
              f"{args.checkpoint}")
    front = search.run(args.budget)
    base = search.baseline()

    print(f"[tune] frontier ({len(front)} points; "
          f"hypervolume={search.frontier_hypervolume():.4f}; "
          f"{len(search.records)} evals, {evals['n']} engine runs):")
    for r in front:
        print(f"[tune]   tok/step={-r.objectives[0]:.3f} "
              f"ttft_p95={r.objectives[1]:.2f} "
              f"mJ/tok={r.objectives[2]:.3f}  {_fmt_genome(r.genome)}")
    print(f"[tune] baseline (hand-tuned default): "
          f"tok/step={-base.objectives[0]:.3f} "
          f"ttft_p95={base.objectives[1]:.2f} "
          f"mJ/tok={base.objectives[2]:.3f}")

    doc = materialize(search, trace={
        "kind": trace.kind, "seed": trace.seed,
        "n_requests": trace.n_requests, "rate": trace.rate,
        "arch": args.arch, "smoke_model": True,
        "max_len": space.max_len})
    out_path = args.out or ServingCfg.preset_path()
    write_presets(out_path, doc)
    for name in sorted(doc["presets"]):
        p = doc["presets"][name]
        print(f"[tune] preset {name:<10} "
              f"tok/step={-p['objectives']['throughput']:.3f} "
              f"ttft_p95={p['objectives']['latency']:.2f} "
              f"mJ/tok={p['objectives']['energy']:.3f}")
    print(f"[tune] wrote {len(doc['presets'])} presets "
          f"({len(front)}-point frontier) to {out_path} "
          f"in {time.time() - t0:.1f}s")

    if args.smoke:
        objs = [r.objectives for r in front]
        assert len(pareto_front(objs)) == len(objs), (
            "frontier contains dominated points")
        assert len(set(objs)) >= 2, (
            f"frontier has {len(set(objs))} distinct objective vectors "
            "(need >= 2: the trace exposes no knob tradeoff)")
        assert len(doc["presets"]) >= 3, "fewer than 3 named presets"
        for axis, name in enumerate(("throughput", "latency", "energy")):
            got = doc["presets"][name]["objectives"][name]
            ref = base.objectives[axis]
            assert got <= ref + 1e-12, (
                f"preset {name} ({got}) worse than the hand-tuned default "
                f"({ref}) on its own objective")
        # same-seed reproducibility: re-run the ENTIRE search loop (fresh
        # RNG, fresh population state) with evaluations memoized from the
        # first pass — engine results are deterministic for a genome, so
        # this verifies the loop replays the identical proposal sequence
        memo = {space.genome_key(r.genome): (r.objectives, r.metrics)
                for r in search.records}

        def replay(genome):
            return memo[space.genome_key(genome)]

        search2 = ParetoSearch(space, replay, seed=args.seed, mu=args.mu,
                               lam=args.lam, mutate_p=args.mutate_p,
                               crossover_p=args.crossover_p)
        front2 = search2.run(args.budget)
        assert [space.genome_key(r.genome) for r in search2.records] == \
            [space.genome_key(r.genome) for r in search.records], (
            "same-seed search proposed a different evaluation sequence")
        assert [r.objectives for r in front2] == [r.objectives
                                                  for r in front], (
            "same-seed search produced a different frontier")
        print(f"[tune] smoke PASS: non-dominated frontier "
              f"({len(set(objs))} distinct points), "
              f"{len(doc['presets'])} presets each >= default on its axis, "
              "same-seed frontier reproduced exactly")
    return 0


if __name__ == "__main__":
    sys.exit(main())
