import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

# ruff: noqa: E402  (the XLA flag MUST precede any jax import)
"""Multi-pod dry-run: lower + compile every (arch x shape) on the production
meshes, record memory/cost/collective analysis for the roofline.

  PYTHONPATH=src python -m repro.launch.dryrun --arch musicgen-large --shape decode_32k
  PYTHONPATH=src python -m repro.launch.dryrun --all            # 40-cell baseline
  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod

Outputs one JSON per cell under experiments/dryrun/.
"""
import argparse
import json
import math
import time
from functools import partial
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, ASSIGNED, SHAPES, cell_supported, get_config
from repro.distributed.cache_specs import cache_pspecs
from repro.distributed.rules import act_rules, param_rules
from repro.distributed.sharding import sharding_context
from repro.distributed import hlo_analysis
from repro.launch import input_specs as ispec
from repro.launch.mesh import make_production_mesh, mesh_shape_dict
from repro.models import model as M
from repro.optim import adafactor, adamw, cosine_schedule
from repro.train.step import TrainStepCfg, make_train_step

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def pick_optimizer(cfg):
    """adafactor for models whose f32 adam moments would not fit 16GB/chip."""
    from repro.common.param import count_params
    n = count_params(M.model_defs(cfg))
    return ("adafactor", adafactor(cosine_schedule(1e-4, 100, 10000))) if n > 5e10 \
        else ("adamw", adamw(cosine_schedule(3e-4, 100, 10000)))


def suggest_microbatches(cfg, shape, mesh) -> int:
    """Keep the per-device scan-carry activation footprint under ~2GB."""
    ms = mesh_shape_dict(mesh)
    dp = ms.get("data", 1) * ms.get("pod", 1)
    b_loc = max(shape.global_batch // dp, 1)
    carry = b_loc * shape.seq_len * cfg.d_model * 2 * max(cfg.num_blocks, 1)
    target = 2.0e9
    k = 1
    while carry / k > target and k < b_loc:
        k *= 2
    while shape.global_batch % (k * dp) and k > 1:
        k //= 2
    return k


def named(mesh, tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree,
        is_leaf=lambda x: isinstance(x, P))


def lower_cell(arch: str, shape_name: str, multi_pod: bool, mode: str | None = None):
    """Returns (lowered, meta). mode overrides the attention runtime."""
    cfg = get_config(arch)
    if mode and mode != "dense":
        cfg = cfg.with_attention(mode)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    arules = act_rules(multi_pod)
    prules = param_rules(multi_pod)
    pspecs = M.param_specs(cfg, prules, mesh_shape_dict(mesh))
    abstract = M.abstract_params(cfg)
    meta = {
        "arch": arch, "shape": shape_name, "mode": mode or cfg.attention.mode,
        "mesh": "pod2x16x16" if multi_pod else "16x16",
        "devices": int(math.prod(mesh.devices.shape)),
        "kind": shape.kind,
    }

    with sharding_context(mesh, arules):
        if shape.kind == "train":
            opt_name, opt = pick_optimizer(cfg)
            mb = suggest_microbatches(cfg, shape, mesh)
            meta.update(optimizer=opt_name, microbatches=mb)
            tstep = make_train_step(cfg, opt, TrainStepCfg(microbatches=mb))
            batch, bspecs = ispec.train_inputs(cfg, shape, mesh)
            ospecs = opt.state_specs(pspecs, abstract)
            ostate = jax.eval_shape(opt.state_like, abstract)
            mspec = {"nll": P(), "aux": P(), "loss": P()}
            fn = jax.jit(
                tstep,
                in_shardings=(named(mesh, pspecs), named(mesh, ospecs), None,
                              named(mesh, bspecs)),
                out_shardings=(named(mesh, pspecs), named(mesh, ospecs),
                               named(mesh, mspec)),
                donate_argnums=(0, 1),
            )
            lowered = fn.lower(abstract, ostate, jax.ShapeDtypeStruct((), jnp.int32),
                               batch)
        elif shape.kind == "prefill":
            rt = cfg.attention
            batch, bspecs, caches, cspecs = ispec.prefill_inputs(cfg, rt, shape, mesh)
            lspec = P(bspecs[next(iter(bspecs))][0], "model")

            def prefill_fn(params, batch, caches):
                return M.prefill(cfg, rt, params, batch, caches)

            fn = jax.jit(
                prefill_fn,
                in_shardings=(named(mesh, pspecs), named(mesh, bspecs),
                              named(mesh, cspecs)),
                out_shardings=(named(mesh, lspec), named(mesh, cspecs)),
                donate_argnums=(2,),
            )
            lowered = fn.lower(abstract, batch, caches)
        else:  # decode
            rt = cfg.attention
            tokens, tspec, pos, caches, cspecs = ispec.decode_inputs(cfg, rt, shape, mesh)
            lspec = P(tspec[0], "model")

            def serve_step(params, tokens, pos, caches):
                return M.decode_step(cfg, rt, params, tokens, pos, caches)

            fn = jax.jit(
                serve_step,
                in_shardings=(named(mesh, pspecs), named(mesh, tspec), None,
                              named(mesh, cspecs)),
                out_shardings=(named(mesh, lspec), named(mesh, cspecs)),
                donate_argnums=(3,),
            )
            lowered = fn.lower(abstract, tokens, pos, caches)
    return lowered, meta


def run_cell(arch: str, shape_name: str, multi_pod: bool, mode: str | None = None,
             out_dir: Path = OUT_DIR, save: bool = True) -> dict:
    cfg = get_config(arch)
    if mode and mode != "dense":
        cfg = cfg.with_attention(mode)
    shape = SHAPES[shape_name]
    ok, why = cell_supported(cfg, shape)
    tag = f"{arch}__{shape_name}__{'pod2' if multi_pod else 'pod1'}__{mode or cfg.attention.mode}"
    if not ok:
        rec = {"arch": arch, "shape": shape_name, "skipped": True, "why": why,
               "mesh": "pod2x16x16" if multi_pod else "16x16"}
        print(f"[dryrun] SKIP {tag}: {why}")
    else:
        t0 = time.time()
        lowered, meta = lower_cell(arch, shape_name, multi_pod, mode)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

        cost = compiled.cost_analysis() or {}
        try:
            mem = compiled.memory_analysis()
            mem_d = {
                "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
                "output_bytes": getattr(mem, "output_size_in_bytes", None),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
                "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
                "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
            }
        except Exception as e:  # pragma: no cover
            mem_d = {"error": str(e)}
        hlo = compiled.as_text()
        # trip-count-aware per-device analysis (XLA's cost_analysis counts
        # while bodies once — see hlo_analysis docstring)
        cost_hlo = hlo_analysis.analyze(hlo)
        rec = dict(
            meta,
            skipped=False,
            lower_s=round(t_lower, 2),
            compile_s=round(t_compile, 2),
            flops_per_device=cost_hlo.flops,
            bytes_per_device=cost_hlo.bytes,
            collective_bytes_per_device=cost_hlo.collectives,
            collective_total=cost_hlo.collective_total,
            xla_flops_unscaled=cost.get("flops"),
            memory=mem_d,
            trip_counts=sorted(set(hlo_analysis.while_trip_counts(hlo)))[-8:],
        )
        print(f"[dryrun] OK   {tag}: compile={t_compile:.1f}s "
              f"flops/dev={rec['flops_per_device']:.3e} "
              f"bytes/dev={rec['bytes_per_device']:.3e} "
              f"coll/dev={rec['collective_total']:.3e}B "
              f"temp={mem_d.get('temp_bytes')}")
    if save:
        out_dir.mkdir(parents=True, exist_ok=True)
        (out_dir / f"{tag}.json").write_text(json.dumps(rec, indent=1))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default=None)
    ap.add_argument("--shape", choices=sorted(SHAPES), default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--mode", default=None,
                    choices=[None, "dense", "decomposed", "cpq", "retrieval", "decomposed_cpq"])
    ap.add_argument("--all", action="store_true", help="all 40 assigned cells")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--out", default=str(OUT_DIR))
    args = ap.parse_args()
    out = Path(args.out)

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    if args.all:
        cells = [(a, s) for a in ASSIGNED for s in SHAPES]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    failures = []
    for arch, shape_name in cells:
        for mp in meshes:
            mode = args.mode
            cfg = get_config(arch)
            if (args.all and shape_name == "long_500k"
                    and not cfg.sub_quadratic and mode is None):
                # paper's T3 makes the full-attention long-context cell runnable
                mode = "retrieval"
            if args.skip_existing:
                cfg2 = get_config(arch)
                if mode and mode != "dense":
                    cfg2 = cfg2.with_attention(mode)
                tag = (f"{arch}__{shape_name}__{'pod2' if mp else 'pod1'}"
                       f"__{mode or cfg2.attention.mode}")
                if (out / f"{tag}.json").exists():
                    continue
            try:
                run_cell(arch, shape_name, mp, mode, out)
            except Exception as e:
                failures.append((arch, shape_name, mp, str(e)[:200]))
                print(f"[dryrun] FAIL {arch}/{shape_name}/mp={mp}: {e}")
    if failures:
        raise SystemExit(f"{len(failures)} dryrun failures: {failures}")


if __name__ == "__main__":
    main()
