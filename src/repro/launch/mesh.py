"""Production meshes. A FUNCTION (not module-level constant) so importing
this module never touches jax device state."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_serve_mesh(dp: int = 1, mp: int = 1):
    """Serving mesh ("data", "model"): ``mp``-way model sharding partitions
    every paged arena's kv-head (or latent feature) axis — per-device HBM
    holds 1/mp of the cache and each device sweeps only its head shard
    (serving/sharded.py); ``dp`` replicates the engine (arenas + params) for
    throughput. Host-platform runs emulate devices via
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N``."""
    n = len(jax.devices())
    if dp * mp > n:
        raise ValueError(f"mesh ({dp},{mp}) needs {dp * mp} devices, have {n} "
                         "(on CPU set XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N)")
    return jax.make_mesh((dp, mp), ("data", "model"))


def parse_mesh_arg(arg: str):
    """CLI ``--mesh dp,mp`` -> Mesh (e.g. "1,2")."""
    try:
        dp, mp = (int(x) for x in arg.split(","))
    except ValueError as e:
        raise ValueError(f"--mesh wants 'dp,mp' (e.g. 1,2); got {arg!r}") from e
    return make_serve_mesh(dp, mp)


def mesh_shape_dict(mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))
