"""Serving driver — batched generation with mode-selectable caches.

  PYTHONPATH=src python -m repro.launch.serve --arch musicgen-large --smoke \
      --mode decomposed --batch 4 --prompt 64 --new 16

Prints per-mode decode cache bytes/token next to throughput so the paper's
T1/T2/T3 traffic story is visible from the CLI.
"""
from __future__ import annotations

import argparse
import sys
import time

from repro.launch._bootstrap import ensure_host_devices_for_mesh

# --mesh needs the emulated host devices BEFORE the jax backend initializes
ensure_host_devices_for_mesh(sys.argv)

import jax
import numpy as np

from repro.configs import get_config, smoke_config
from repro.configs.base import ShapeCfg
from repro.data import DataConfig, SyntheticLMData
from repro.models import model as M
from repro.serving import GenerationConfig, ServeEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--mode", default=None,
                    choices=[None, "dense", "decomposed", "cpq", "retrieval", "decomposed_cpq"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt", type=int, default=64)
    ap.add_argument("--new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--continuous", action="store_true",
                    help="continuous-batching engine over paged arenas "
                         "(token prompts only)")
    ap.add_argument("--preset", default=None, metavar="NAME",
                    help="serving preset from the auto-tuner's materialized "
                         "Pareto frontier (latency | throughput | energy | "
                         "default; src/repro/configs/serving_presets.json, "
                         "see docs/tuning.md). Supplies the tuned knobs "
                         "(policy, page_size, prefill_chunk, num_slots, "
                         "watermarks, speculation); arena capacity is "
                         "re-derived for --prompt/--new. Requires "
                         "--continuous; conflicts with explicit --policy/"
                         "--prefill-chunk/--speculate")
    ap.add_argument("--prefill-chunk", type=int, default=16,
                    help="chunked paged prefill: prompts stream into arena "
                         "pages in chunks of this many tokens, interleaved "
                         "with decode (page-aligned; 0 = one-shot admission)")
    ap.add_argument("--speculate", type=int, default=0, metavar="K",
                    help="speculative decoding: draft up to K tokens per row "
                         "by prompt lookup (n-gram over the row's own "
                         "context) and verify them in ONE chunked paged "
                         "attend — accepted tokens land in the same tick "
                         "(serving/speculative.py; greedy output is "
                         "bit-identical on/off; requires --continuous and a "
                         "chunked dense/decomposed engine; 0 = off)")
    ap.add_argument("--policy", default="fifo",
                    choices=["fifo", "priority", "slo"],
                    help="scheduler policy (serving/policies.py): fifo = "
                         "arrival order (default), priority = strict "
                         "SloClass levels + aging, slo = TTFT-slack EDF "
                         "admission with T2->dense de-escalation "
                         "(requires --continuous)")
    ap.add_argument("--replicas", type=int, default=1,
                    help="data-parallel engine replicas behind a "
                         "ReplicaRouter (serving/router.py); each replica "
                         "owns its own scheduler and paged arenas and the "
                         "router spreads requests over them (requires "
                         "--continuous)")
    ap.add_argument("--placement", default="rr",
                    choices=["rr", "load", "slo"],
                    help="router placement policy: rr = round-robin, load = "
                         "least outstanding tokens, slo = latency-bound "
                         "classes to the freest arena, deadline-free batch "
                         "balanced by outstanding tokens (only with "
                         "--replicas > 1)")
    ap.add_argument("--probe-interval", type=int, default=4,
                    help="router health-probe period in ticks (0 disables "
                         "periodic probing; step() faults still count); "
                         "liveness / arena-pressure / progress checks "
                         "(serving/health.py; with --replicas > 1)")
    ap.add_argument("--auto-drain", action="store_true",
                    help="drain a replica that fails consecutive health "
                         "probes (or crashes in step()) and re-admit it "
                         "after a backoff recovery probe succeeds; its "
                         "in-flight work migrates by recompute replay "
                         "(requires --replicas > 1)")
    ap.add_argument("--deadline-scale", type=float, default=0.0,
                    help="derive per-request tick deadlines from the SLO "
                         "class targets (deadline = scale * (ttft_target + "
                         "max_tokens * itl_target)); blown budgets finish "
                         "with reason 'timeout' instead of occupying slots; "
                         "0 = off (requires --continuous)")
    ap.add_argument("--inject-faults", type=int, default=None, metavar="SEED",
                    help="wrap every replica in a deterministic seed-driven "
                         "fault plan (crash / stall / exhaust windows; "
                         "serving/faults.py) to exercise the auto-drain and "
                         "recovery machinery (requires --replicas > 1; "
                         "implies --auto-drain)")
    ap.add_argument("--mesh", default=None, metavar="dp,mp",
                    help="serve over a device mesh: dp-way engine replication"
                         " x mp-way model sharding of the paged arenas "
                         "(kv-head axis; requires --continuous). On CPU, "
                         "devices are emulated via XLA_FLAGS.")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_config(cfg)
    if args.mode:
        cfg = cfg.with_attention(args.mode)

    key = jax.random.PRNGKey(args.seed)
    params = M.init_params(cfg, key)
    shape = ShapeCfg("serve", args.prompt, args.batch, "prefill")
    batch = SyntheticLMData(cfg, shape, DataConfig(seed=args.seed)).batch(0)
    batch.pop("labels")
    batch = {k: jax.numpy.asarray(v) for k, v in batch.items()}

    if args.policy != "fifo" and not args.continuous:
        ap.error("--policy requires --continuous (the static engine has no "
                 "admission queue)")
    if args.replicas < 1:
        ap.error("--replicas must be >= 1")
    if args.replicas > 1 and not args.continuous:
        ap.error("--replicas requires --continuous (the router fans out "
                 "over continuous-batching engines)")
    if args.speculate and not args.continuous:
        ap.error("--speculate requires --continuous (drafts alias paged "
                 "arenas and verify through the chunked prefill path)")
    if args.speculate < 0:
        ap.error("--speculate must be >= 0 (0 disables)")
    if args.speculate and args.prefill_chunk == 0:
        ap.error("--speculate requires chunked admission (--prefill-chunk "
                 "> 0): the verify pass is a spec_len+1 wide prefill chunk")
    if args.preset:
        if not args.continuous:
            ap.error("--preset requires --continuous (presets are tuned "
                     "continuous-serving operating points)")
        for flag, dest in (("--policy", "policy"),
                           ("--prefill-chunk", "prefill_chunk"),
                           ("--speculate", "speculate")):
            if getattr(args, dest) != ap.get_default(dest):
                ap.error(f"--preset sets {flag}; drop the explicit flag "
                         "(or drop --preset to hand-tune)")
    if args.deadline_scale and not args.continuous:
        ap.error("--deadline-scale requires --continuous (tick deadlines "
                 "are enforced by the continuous scheduler)")
    if args.deadline_scale < 0:
        ap.error("--deadline-scale must be >= 0")
    if args.auto_drain and args.replicas < 2:
        ap.error("--auto-drain requires --replicas > 1 (the HealthMonitor "
                 "lives in the router)")
    if args.inject_faults is not None and args.replicas < 2:
        ap.error("--inject-faults requires --replicas > 1 (faults exercise "
                 "the router's drain/recovery machinery)")
    mesh = None
    if args.mesh:
        if not args.continuous:
            ap.error("--mesh requires --continuous (paged arenas)")
        from repro.launch.mesh import parse_mesh_arg

        mesh = parse_mesh_arg(args.mesh)

    if args.continuous:
        from repro.configs import ServingCfg
        from repro.serving import ContinuousServeEngine
        from repro.serving.paged_cache import pages_needed

        n_max = args.prompt + args.new
        if args.preset:
            # tuned knobs from the materialized frontier; capacity re-derived
            # for THIS context ceiling (the tuner sized its arena for the
            # smoke trace, not for --prompt/--new)
            base = ServingCfg.from_preset(args.preset)
            serving = ServingCfg.from_preset(
                args.preset,
                num_pages=base.num_slots * pages_needed(n_max, base.page_size) + 1,
                max_blocks_per_slot=pages_needed(n_max, base.page_size),
                prefill_bucket=base.prefill_chunk or base.page_size,
                probe_interval=args.probe_interval,
                auto_drain=args.auto_drain or args.inject_faults is not None,
                deadline_scale=args.deadline_scale)
            print(f"[serve] preset={args.preset}: policy={serving.policy} "
                  f"page_size={serving.page_size} "
                  f"prefill_chunk={serving.prefill_chunk} "
                  f"num_slots={serving.num_slots} "
                  f"spec_len={serving.spec_len}")
        else:
            serving = ServingCfg(
                num_slots=args.batch, page_size=16,
                num_pages=args.batch * pages_needed(n_max, 16) + 1,
                max_blocks_per_slot=pages_needed(n_max, 16), prefill_bucket=16,
                prefill_chunk=args.prefill_chunk, policy=args.policy,
                probe_interval=args.probe_interval,
                auto_drain=args.auto_drain or args.inject_faults is not None,
                deadline_scale=args.deadline_scale, spec_len=args.speculate)
        if args.replicas > 1:
            from repro.serving import ReplicaRouter

            plans = None
            if args.inject_faults is not None:
                from repro.serving.faults import FaultPlan

                plans = [FaultPlan.random(args.inject_faults + i,
                                          horizon=4 * args.new, n_events=2)
                         for i in range(args.replicas)]
            eng = ReplicaRouter(cfg, params, num_replicas=args.replicas,
                                serving=serving, placement=args.placement,
                                mesh=mesh, fault_plans=plans)
            print(f"[serve] router: {args.replicas} replicas, "
                  f"placement={args.placement} "
                  f"({args.replicas * args.batch} slots aggregate)")
            if plans is not None:
                events = "; ".join(
                    f"r{i}:" + ",".join(f"{e.kind}@{e.tick}x{e.duration}"
                                        for e in p.events)
                    for i, p in enumerate(plans))
                print(f"[serve] fault injection seed={args.inject_faults}: "
                      f"{events} (auto-drain on)")
        else:
            eng = ContinuousServeEngine(cfg, params, serving=serving,
                                        mesh=mesh)
        print(f"[serve] policy={serving.policy}; chunked prefill: "
              f"{'on, chunk=' + str(serving.prefill_chunk) if eng.chunked else 'off (one-shot admission)'}")
        if serving.spec_len:
            on = getattr(eng, "spec_on",
                         args.replicas > 1)  # router: per-replica gate
            print(f"[serve] speculative decoding: "
                  f"{f'on, k={serving.spec_len} (prompt lookup)' if on else 'requested but gated off (needs chunked dense/decomposed)'}")
        if mesh is not None:
            print(f"[serve] mesh: data={mesh.shape['data']} "
                  f"model={mesh.shape['model']} "
                  f"(arenas sharded over the kv-head axis)")
    else:
        eng = ServeEngine(cfg, params, max_len=args.prompt + args.new)
    gen = GenerationConfig(max_new_tokens=args.new, temperature=args.temperature,
                           seed=args.seed)
    t0 = time.time()
    out, stats = eng.generate(batch, gen)
    dt = time.time() - t0

    from repro.core import kv_cache as kvc
    from repro.models.attention_layer import decoupled_rope_dims
    mode = cfg.attention.mode
    if mode == "dense":
        bpt = 2.0 * cfg.num_kv_heads * cfg.head_dim * 2
    elif mode == "decomposed":
        bpt = (cfg.d_model + cfg.num_kv_heads * decoupled_rope_dims(cfg)) * 2.0
    elif mode == "cpq":
        from repro.core.cpq import cpq_bytes_per_token
        bpt = 2 * cpq_bytes_per_token(cfg.attention.cpq, cfg.num_kv_heads, cfg.head_dim)
    elif mode == "decomposed_cpq":  # T1+T2: CPQ codes over the X cache
        from repro.core.cpq import cpq_bytes_per_token
        bpt = (cpq_bytes_per_token(cfg.attention.cpq, 1, cfg.d_model)
               + cfg.num_kv_heads * decoupled_rope_dims(cfg) * 2.0)
    else:  # retrieval: dense cache + proxy codes; V reads drop to top_k
        bpt = 2.0 * cfg.num_kv_heads * cfg.head_dim * 2 + cfg.num_kv_heads * cfg.head_dim

    if args.continuous and mesh is not None:
        print(f"[serve] arena: {stats['arena_bytes_per_device'] / 2**20:.2f} "
              f"MiB/device of {stats['arena_bytes_total'] / 2**20:.2f} MiB "
              f"total; interconnect "
              f"{stats['interconnect_bytes_per_token']:.1f} B/token "
              "(per-head partial concat + latent pool gathers)")
    if args.replicas > 1:
        rows = ", ".join(
            f"r{p['replica']}: {p['generated_tokens']} tok @ "
            f"{p['tokens_per_step']:.2f}/step"
            for p in stats["per_replica"])
        print(f"[serve] router aggregate: "
              f"{stats['tokens_per_step']:.2f} tok/step over "
              f"{stats['decode_steps_max']} lockstep ticks ({rows})")
    print(f"[serve] arch={cfg.name} mode={mode}")
    print(f"[serve] generated {out.shape} in {dt:.2f}s "
          f"({out.size / max(dt, 1e-9):.1f} tok/s batch-aggregate)")
    print(f"[serve] decode cache traffic: {bpt:.1f} B/token/layer "
          f"({cfg.num_layers * bpt / 1024:.1f} KiB/token end-to-end)")
    print(f"[serve] sample row: {out[0][:16].tolist()}")
    return out


if __name__ == "__main__":
    main()
