"""Pre-jax process bootstrap helpers.

This module must import NOTHING that initializes the jax backend: its whole
point is to mutate ``XLA_FLAGS`` before the first ``import jax`` runs.
"""
from __future__ import annotations

import os


def ensure_host_devices_for_mesh(argv, n: int = 8, flag: str = "--mesh") -> None:
    """If ``flag`` (or ``flag=value``) appears in ``argv``, force ``n``
    emulated host-platform devices unless a device count is already pinned.
    Call BEFORE importing jax — the backend reads XLA_FLAGS exactly once."""
    if not any(a == flag or a.startswith(flag + "=") for a in argv):
        return
    if "xla_force_host_platform_device_count" in os.environ.get("XLA_FLAGS", ""):
        return
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={n} "
        + os.environ.get("XLA_FLAGS", ""))
