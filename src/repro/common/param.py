"""Parameter-definition trees.

Every model in the framework is described once as a pytree of ``ParamDef``s.
From that single definition we derive:
  * materialized parameters         (``init_tree``)
  * ShapeDtypeStruct stand-ins      (``abstract_tree`` — used by the dry-run)
  * PartitionSpecs via logical axes (``spec_tree`` — used by pjit)

This keeps init / sharding / dry-run in lockstep: a new parameter cannot be
added without declaring its logical sharding axes.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec


@dataclasses.dataclass(frozen=True)
class ParamDef:
    """A single parameter: shape, dtype, logical sharding axes, initializer."""

    shape: tuple[int, ...]
    dtype: jnp.dtype
    # one logical axis name (or None) per dim, e.g. ("embed", "mlp").
    axes: tuple[Optional[str], ...]
    init: str = "normal"  # normal | zeros | ones | scaled(fan_in)
    scale: float = 1.0
    # which dim is the fan-in for init="fan_in"; stack_defs shifts this so
    # stacking layers for scan does NOT change the initialization statistics
    fan_in_dim: int = 0

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)

    def materialize(self, key: jax.Array) -> jax.Array:
        if self.init == "zeros":
            return jnp.zeros(self.shape, self.dtype)
        if self.init == "ones":
            return jnp.ones(self.shape, self.dtype)
        if self.init == "normal":
            std = self.scale
            return (jax.random.normal(key, self.shape, jnp.float32) * std).astype(self.dtype)
        if self.init == "fan_in":
            d = min(self.fan_in_dim, len(self.shape) - 1)
            fan_in = max(self.shape[d], 1) if len(self.shape) >= 2 else max(self.shape[0], 1)
            std = self.scale / math.sqrt(fan_in)
            return (jax.random.normal(key, self.shape, jnp.float32) * std).astype(self.dtype)
        if self.init == "s4d":
            # S4D-real A init: A = -(1..d_state) per channel; stored as log
            n = self.shape[-1]
            a = jnp.log(jnp.arange(1, n + 1, dtype=jnp.float32))
            return jnp.broadcast_to(a, self.shape).astype(self.dtype)
        raise ValueError(f"unknown init {self.init}")

    def abstract(self) -> jax.ShapeDtypeStruct:
        return jax.ShapeDtypeStruct(self.shape, self.dtype)


def _is_def(x) -> bool:
    return isinstance(x, ParamDef)


def init_tree(defs, key: jax.Array):
    """Materialize a pytree of ParamDefs with per-leaf folded keys."""
    leaves, treedef = jax.tree.flatten(defs, is_leaf=_is_def)
    out = []
    for i, leaf in enumerate(leaves):
        out.append(leaf.materialize(jax.random.fold_in(key, i)))
    return jax.tree.unflatten(treedef, out)


def abstract_tree(defs):
    return jax.tree.map(lambda d: d.abstract(), defs, is_leaf=_is_def)


def spec_tree(defs, rules: dict[str, Optional[str]],
              mesh_shape: Optional[dict[str, int]] = None):
    """Map logical axes -> mesh axes. rules maps logical name -> mesh axis,
    tuple of mesh axes, or None. With ``mesh_shape``, axes that do not divide
    the dimension are dropped (e.g. 4 sLSTM heads over a 16-way model axis)."""

    def one(d: ParamDef) -> PartitionSpec:
        mesh_axes = []
        seen = set()
        for dim, ax in zip(d.shape, d.axes):
            m = rules.get(ax) if ax is not None else None
            ms = () if m is None else ((m,) if isinstance(m, str) else tuple(m))
            ms = tuple(a for a in ms if a not in seen)
            if mesh_shape is not None:
                kept = []
                prod = 1
                for a in ms:
                    k = mesh_shape.get(a, 1)
                    if dim % (prod * k) == 0:
                        kept.append(a)
                        prod *= k
                ms = tuple(kept)
            seen.update(ms)
            mesh_axes.append(ms if len(ms) > 1 else (ms[0] if ms else None))
        return PartitionSpec(*mesh_axes)

    return jax.tree.map(one, defs, is_leaf=_is_def)


def count_params(defs) -> int:
    leaves = jax.tree.leaves(defs, is_leaf=_is_def)
    return sum(math.prod(l.shape) for l in leaves)


def stack_defs(defs, n: int, axis_name: Optional[str] = None):
    """Stack a layer's ParamDef tree n times along a new leading dim (for scan)."""

    def one(d: ParamDef) -> ParamDef:
        return ParamDef(
            shape=(n,) + d.shape,
            dtype=d.dtype,
            axes=(axis_name,) + d.axes,
            init=d.init,
            scale=d.scale,
            fan_in_dim=d.fan_in_dim + 1,
        )

    return jax.tree.map(one, defs, is_leaf=_is_def)
