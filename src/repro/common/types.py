"""Shared dtype aliases used across the framework."""
import jax.numpy as jnp

Dtype = jnp.dtype

bf16 = jnp.bfloat16
f32 = jnp.float32
f16 = jnp.float16
i32 = jnp.int32
i8 = jnp.int8
u8 = jnp.uint8
i4 = jnp.int4
