from repro.common.param import ParamDef, init_tree, abstract_tree, spec_tree, count_params
from repro.common.types import Dtype, bf16, f32, i32
