"""T1b — Sub-matrix pipeline (paper §III-B), TPU adaptation + schedule model.

On ReRAM the sub-matrix pipeline streams row-blocks of Q through two
crossbars so both stay busy. The TPU analogues (see DESIGN.md §2):

  1. *kernel fusion* — the Pallas decomposed-attention kernel streams X
     blocks through both cascaded MatMuls per grid step (never materializing
     R = Q·W_Kᵀ scores in HBM); realized in kernels/decomposed_attn.
  2. *collective overlap* — for sequence-parallel caches, per-block
     ``ppermute`` of the next X block overlaps with compute on the current
     one; realized in distributed/collectives.py (flash-decoding combine).

This module keeps the *analytical schedule model* used by
benchmarks/bench_pipeline.py to reproduce the paper's Fig. 3 utilization
comparison: layer-level pipeline vs sub-matrix pipeline for the two cascaded
MatMuls R = Q·W_Kᵀ and Out = R·Xᵀ.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class StageCost:
    """Per-sub-matrix execution time of one pipeline stage (arbitrary units)."""

    t_stage1: float  # one Q sub-block through W_K^T
    t_stage2: float  # one R sub-block through X^T


def layer_level_latency(n_sub: int, c: StageCost) -> float:
    """Stage 2 starts only after ALL of stage 1 finished (Fig. 3a)."""
    return n_sub * c.t_stage1 + n_sub * c.t_stage2


def submatrix_latency(n_sub: int, c: StageCost) -> float:
    """Stage 2 starts as soon as the first sub-block of R exists (Fig. 3b)."""
    bottleneck = max(c.t_stage1, c.t_stage2)
    return c.t_stage1 + n_sub * bottleneck + (c.t_stage2 if c.t_stage1 > c.t_stage2 else 0.0)


def utilization(n_sub: int, c: StageCost, latency: float) -> float:
    """Fraction of (2 units x latency) spent doing useful work."""
    work = n_sub * (c.t_stage1 + c.t_stage2)
    return work / (2.0 * latency)


def speedup(n_sub: int, c: StageCost) -> float:
    return layer_level_latency(n_sub, c) / submatrix_latency(n_sub, c)
