"""Decode-cache containers for every attention mode.

All containers are NamedTuple pytrees with a static arena size ``n_max`` and
a scalar ``length`` (number of valid tokens; decode writes at slot
``length``). Shapes:

  B = batch, N = n_max, H = query heads, KV = kv heads, Dh = head_dim,
  Dm = d_model, R = decoupled-rope dims (T1 on RoPE archs), Dp = proxy dims.

Mode -> container:
  dense      DenseKVCache   K,V                      2*KV*Dh        per token
  decomposed XCache         X (+ small roped keys)   Dm + KV*R      per token (T1)
  cpq        CPQKVCache     CPQ(K), CPQ(V)           ~2*KV*Dh*b/8   per token (T2)
  retrieval  RetrievalCache K,V + int8 proxy codes   2*KV*Dh + Dp   per token (T3)
  cpq+decomp CPQXCache      CPQ(X) (+ roped keys)    ~Dm*b/8        per token (T1+T2)
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import CPQCfg, RetrievalCfg
from repro.core import cpq as cpq_lib


class DenseKVCache(NamedTuple):
    k: jax.Array        # (B, N, KV, Dh)
    v: jax.Array        # (B, N, KV, Dh)
    length: jax.Array   # () int32


class XCache(NamedTuple):
    """T1: cache the layer input X instead of K and V (paper §III)."""

    x: jax.Array        # (B, N, Dm) — the exact input to the K/V projections
    k_rope: jax.Array   # (B, N, KV, R) — decoupled roped key slice (R may be 0)
    length: jax.Array


class CPQKVCache(NamedTuple):
    k: cpq_lib.CPQTensor
    v: cpq_lib.CPQTensor
    length: jax.Array


class RetrievalCache(NamedTuple):
    k: jax.Array            # (B, N, KV, Dh)
    v: jax.Array            # (B, N, KV, Dh)
    proxy: jax.Array        # (B, N, KV, Dp) int8 proxy codes (CAM analogue)
    proxy_scale: jax.Array  # (B, KV, Dp) f32
    proxy_zero: jax.Array   # (B, KV, Dp) f32
    length: jax.Array


class CPQXCache(NamedTuple):
    x: cpq_lib.CPQTensor    # quantized X arena, channels = Dm split as (H=1, D=Dm)
    k_rope: jax.Array       # (B, N, KV, R)
    length: jax.Array


Cache = DenseKVCache | XCache | CPQKVCache | RetrievalCache | CPQXCache


# ------------------------------------------------------------------- helpers


def valid_mask(length: jax.Array, n_max: int) -> jax.Array:
    """(N,) bool — True for written slots."""
    return jnp.arange(n_max, dtype=jnp.int32) < length


def length_mask(length: jax.Array, n: int) -> jax.Array:
    """(B|1, N) bool mask of written cache slots. ``length`` is () for the
    single-sequence arenas or (B,) for per-row paged serving lengths."""
    pos_j = jnp.arange(n, dtype=jnp.int32)
    return pos_j[None, :] < jnp.reshape(length, (-1, 1))


def append_tokens(arena: jax.Array, new: jax.Array, pos: jax.Array) -> jax.Array:
    """Write ``new`` (B, T, ...) into ``arena`` (B, N, ...) at token slot pos."""
    return jax.lax.dynamic_update_slice_in_dim(arena, new.astype(arena.dtype), pos, axis=1)


# ------------------------------------------------------------- constructors


def init_dense(batch: int, n_max: int, kv: int, dh: int, dtype=jnp.bfloat16) -> DenseKVCache:
    z = jnp.zeros((batch, n_max, kv, dh), dtype)
    return DenseKVCache(z, z, jnp.zeros((), jnp.int32))


def init_x(batch: int, n_max: int, dm: int, kv: int, rope_dims: int,
           dtype=jnp.bfloat16) -> XCache:
    return XCache(
        x=jnp.zeros((batch, n_max, dm), dtype),
        k_rope=jnp.zeros((batch, n_max, kv, rope_dims), dtype),
        length=jnp.zeros((), jnp.int32),
    )


def _empty_cpq(batch: int, n_max: int, h: int, d: int, cfg: CPQCfg) -> cpq_lib.CPQTensor:
    return cpq_lib.CPQTensor(
        codes=jnp.zeros((batch, n_max, h, d), jnp.int8),
        scale=jnp.zeros((batch, cfg.max_levels, h, d), jnp.float32),
        zero=jnp.zeros((batch, cfg.max_levels, h, d), jnp.float32),
        level=jnp.zeros((batch, n_max, h), jnp.int32),
        num_levels=jnp.ones((batch, h), jnp.int32),
        prune_thr=jnp.zeros((batch, h, d), jnp.float32),
    )


def init_cpq(batch: int, n_max: int, kv: int, dh: int, cfg: CPQCfg) -> CPQKVCache:
    return CPQKVCache(
        k=_empty_cpq(batch, n_max, kv, dh, cfg),
        v=_empty_cpq(batch, n_max, kv, dh, cfg),
        length=jnp.zeros((), jnp.int32),
    )


def init_retrieval(batch: int, n_max: int, kv: int, dh: int, cfg: RetrievalCfg,
                   dtype=jnp.bfloat16) -> RetrievalCache:
    dp = cfg.proxy_dim or dh
    z = jnp.zeros((batch, n_max, kv, dh), dtype)
    return RetrievalCache(
        k=z,
        v=z,
        proxy=jnp.zeros((batch, n_max, kv, dp), jnp.int8),
        proxy_scale=jnp.ones((batch, kv, dp), jnp.float32),
        proxy_zero=jnp.zeros((batch, kv, dp), jnp.float32),
        length=jnp.zeros((), jnp.int32),
    )


def init_cpq_x(batch: int, n_max: int, dm: int, kv: int, rope_dims: int,
               cfg: CPQCfg, dtype=jnp.bfloat16) -> CPQXCache:
    return CPQXCache(
        x=_empty_cpq(batch, n_max, 1, dm, cfg),
        k_rope=jnp.zeros((batch, n_max, kv, rope_dims), dtype),
        length=jnp.zeros((), jnp.int32),
    )


def bytes_per_token(cache: Cache, cpq_cfg: Optional[CPQCfg] = None) -> float:
    """Off-chip traffic per cached token — ONE accounting API for every
    container. CPQ modes route through ``cpq_lib.cpq_bytes_per_token`` (the
    serving watermark policy depends on every tier reporting through here);
    pass the runtime's ``CPQCfg`` for exact bits/prune accounting, else the
    default CPQCfg is assumed."""
    if isinstance(cache, DenseKVCache):
        return 2.0 * cache.k.shape[2] * cache.k.shape[3] * cache.k.dtype.itemsize
    if isinstance(cache, XCache):
        return (cache.x.shape[2] * cache.x.dtype.itemsize
                + cache.k_rope.shape[2] * cache.k_rope.shape[3] * cache.k_rope.dtype.itemsize)
    if isinstance(cache, RetrievalCache):
        return (2.0 * cache.k.shape[2] * cache.k.shape[3] * cache.k.dtype.itemsize
                + cache.proxy.shape[2] * cache.proxy.shape[3])
    if isinstance(cache, CPQKVCache):
        cfg = cpq_cfg or CPQCfg()
        h, d = cache.k.codes.shape[2], cache.k.codes.shape[3]
        return 2.0 * cpq_lib.cpq_bytes_per_token(cfg, h, d)
    if isinstance(cache, CPQXCache):
        cfg = cpq_cfg or CPQCfg()
        dm = cache.x.codes.shape[3]
        rope = cache.k_rope.shape[2] * cache.k_rope.shape[3] * cache.k_rope.dtype.itemsize
        return cpq_lib.cpq_bytes_per_token(cfg, 1, dm) + rope
    raise TypeError(type(cache))
