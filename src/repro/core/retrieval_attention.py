"""T3 — Attention as nearest-neighbor retrieval (paper §V).

Two-stage reformulation of SDA:
  (1) *proxy similarity*: a cheap associative-match pass over ALL keys —
      the CAM analogue. On TPU we realize the CAM with an int8 (or low-rank)
      code matmul on the MXU: per-channel-quantized key codes are scored
      against the quantized query. Traffic: 1 byte (or Dp bytes) per key
      channel instead of 2; MACs are int8.
  (2) *calibrated re-scoring*: exact bf16 attention restricted to the top-K
      candidates (plus an always-attended recent window), with optional mass
      calibration that rescales the output by the proxy-estimated fraction of
      softmax mass captured by the selected set.

Complexity: dense similarity O(N * Dh) per query in bf16 becomes
O(N * Dp) int8 + O(K * Dh) bf16; V reads drop from N to K.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import RetrievalCfg
from repro.core.kv_cache import length_mask

NEG_INF = -1e30


# ----------------------------------------------------------- proxy encoding


def fit_proxy(k: jax.Array, bits: int = 8):
    """Per-channel affine int8 code fit for keys. k: (B, N, KV, Dp_src).

    Returns (codes int8, scale (B,KV,Dp), zero (B,KV,Dp))."""
    kf = k.astype(jnp.float32)
    lo = jnp.min(kf, axis=1)
    hi = jnp.max(kf, axis=1)
    steps = (1 << bits) - 1
    scale = jnp.maximum((hi - lo) / steps, 1e-8)
    codes = jnp.clip(jnp.round((kf - lo[:, None]) / scale[:, None]), 0, steps)
    return (codes - 128).astype(jnp.int8), scale, lo


def encode_proxy(k_t: jax.Array, scale: jax.Array, zero: jax.Array, bits: int = 8):
    """Encode new tokens with existing proxy parameters. k_t: (B, T, KV, Dp)."""
    steps = (1 << bits) - 1
    codes = jnp.clip(jnp.round((k_t.astype(jnp.float32) - zero[:, None]) / scale[:, None]),
                     0, steps)
    return (codes - 128).astype(jnp.int8)


def proxy_scores(q: jax.Array, codes: jax.Array, scale: jax.Array, zero: jax.Array) -> jax.Array:
    """Approximate q.K^T from codes. q: (B,T,H,Dp), codes: (B,N,KV,Dp).

    score ~= sum_d q_d * (code_d * scale_d + zero_d)
           = (q * scale) . code  +  q . zero        (second term is per-query)
    Returns (B, T, H, N) in f32.
    """
    B, T, H, Dp = q.shape
    KV = codes.shape[2]
    g = H // KV
    qf = q.astype(jnp.float32).reshape(B, T, KV, g, Dp)
    c = (codes.astype(jnp.float32) + 128.0)
    s = jnp.einsum("btkgd,bkd,bnkd->btkgn", qf, scale, c)
    s = s + jnp.einsum("btkgd,bkd->btkg", qf, zero)[..., None]
    return s.reshape(B, T, H, codes.shape[1])


# --------------------------------------------------------------- retrieval


def select_topk(
    s_proxy: jax.Array,    # (B, T, H, N) proxy scores
    length: jax.Array,     # () or (B,) valid tokens
    cfg: RetrievalCfg,
    query_positions: jax.Array | None = None,
) -> jax.Array:
    """Top-K candidate indices per (B, T, H): (B, T, H, K) int32.

    The most recent ``recent_window`` tokens get +inf bias so the dense local
    tail is always attended (standard retrieval-attention practice; keeps the
    calibration well-conditioned)."""
    N = s_proxy.shape[-1]
    pos_j = jnp.arange(N, dtype=jnp.int32)
    len_col = jnp.reshape(length, (-1, 1))                      # (B|1, 1)
    ok = length_mask(length, N)[:, None, :]                     # (B|1, 1, N)
    if query_positions is not None:
        ok = ok & (pos_j[None, :] <= query_positions[:, None])[None]
    s = jnp.where(ok[:, :, None, :], s_proxy, NEG_INF)
    recent = (pos_j[None, :] >= (len_col - cfg.recent_window))[:, None, :]
    if query_positions is not None:
        recent = (pos_j[None, :]
                  >= (query_positions[:, None] - cfg.recent_window + 1))[None]
    s = jnp.where((recent & ok)[:, :, None, :], jnp.float32(1e20), s)
    k = min(cfg.top_k, N)
    _, idx = jax.lax.top_k(s, k)
    return idx.astype(jnp.int32)


def gather_kv(k: jax.Array, v: jax.Array, idx: jax.Array):
    """Gather per-head candidates. k,v: (B,N,KV,Dh); idx: (B,T,H,K).

    Returns k_sel, v_sel: (B, T, H, K, Dh)."""
    B, N, KV, Dh = k.shape
    _, T, H, K = idx.shape
    g = H // KV
    idx_kv = idx.reshape(B, T, KV, g, K)

    def take(x):
        # x: (B, N, KV, Dh) -> (B, KV, N, Dh)
        xt = x.swapaxes(1, 2)
        out = jnp.take_along_axis(
            xt[:, :, None, None],                      # (B, KV, 1, 1, N, Dh)
            idx_kv.transpose(0, 2, 1, 3, 4)[..., None],  # (B, KV, T, g, K, 1)
            axis=4,
        )  # (B, KV, T, g, K, Dh)
        return out.transpose(0, 2, 1, 3, 4, 5).reshape(B, T, H, K, Dh)

    return take(k), take(v)


def retrieval_attention(
    q: jax.Array,          # (B, T, H, Dh) roped query
    k: jax.Array,          # (B, N, KV, Dh) roped keys (arena)
    v: jax.Array,          # (B, N, KV, Dh)
    proxy_codes: jax.Array,
    proxy_scale: jax.Array,
    proxy_zero: jax.Array,
    length: jax.Array,
    cfg: RetrievalCfg,
    scale: float,
    query_positions: jax.Array | None = None,
    calibrate: bool = True,
) -> jax.Array:
    """Full T3 pipeline. Returns (B, T, H, Dh)."""
    B, T, H, Dh = q.shape

    q_proxy = q if cfg.proxy_dim == 0 else q[..., : cfg.proxy_dim]
    sp = proxy_scores(q_proxy * scale, proxy_codes, proxy_scale, proxy_zero)
    idx = select_topk(sp, length, cfg, query_positions)
    k_sel, v_sel = gather_kv(k, v, idx)

    s = jnp.einsum("bthd,bthkd->bthk", q, k_sel).astype(jnp.float32) * scale
    # mask candidates that duplicated an invalid slot (length < K edge case)
    ok = idx < jnp.reshape(length, (-1, 1, 1, 1))               # () or (B,) length
    if query_positions is not None:
        ok = ok & (idx <= query_positions[None, :, None, None])
    s = jnp.where(ok, s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)

    if calibrate:
        # proxy-estimated fraction of total softmax mass captured by the
        # selected set -> rescale so dropped tail is accounted for.
        pos_j = jnp.arange(sp.shape[-1], dtype=jnp.int32)
        okn = length_mask(length, sp.shape[-1])[:, None, :]
        if query_positions is not None:
            okn = okn & (pos_j[None, :] <= query_positions[:, None])[None]
        spm = jnp.where(okn[:, :, None, :], sp, NEG_INF)
        m = jnp.max(spm, axis=-1, keepdims=True)
        denom_all = jnp.sum(jnp.exp(spm - m), axis=-1)
        sp_sel = jnp.take_along_axis(spm, idx, axis=-1)
        denom_sel = jnp.sum(jnp.exp(sp_sel - m), axis=-1)
        frac = jnp.clip(denom_sel / jnp.maximum(denom_all, 1e-30), 0.0, 1.0)
        w = w * frac[..., None]

    return jnp.einsum("bthk,bthkd->bthd", w.astype(v.dtype), v_sel)
