"""The paper's contributions as composable JAX modules.

T1: decomposed_attention / submatrix_pipeline  (§III)
T2: cpq                                        (§IV)
T3: retrieval_attention                        (§V)
attention: mode dispatcher; kv_cache: decode arenas per mode.
"""
from repro.core import (  # noqa: F401
    attention,
    cpq,
    decomposed_attention,
    kv_cache,
    retrieval_attention,
    submatrix_pipeline,
)
