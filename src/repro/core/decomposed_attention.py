"""T1 — Matrix decomposition of scaled dot-product attention (paper §III).

The paper's algebra (exact in real arithmetic):

    scores = Q K^T = Q (X W_K)^T = (Q W_K^T) X^T          ... (score stage)
    out    = S V   = S (X W_V)   = (S X) W_V              ... (value stage)

On ReRAM this removes the crossbar writes of K^T/V. On TPU the cached operand
becomes X (d_model per token) instead of K and V (2*kv*Dh per token): for MHA
(kv*Dh == d_model) decode cache traffic HALVES, and one X read serves both
stages. The extra FLOPs (the score/value stages run in d_model- instead of
Dh-space) sit far below the v5e roofline ridge during decode — see DESIGN.md.

RoPE: position-dependent rotations on K do not commute with W_K, so on RoPE
architectures we use the decoupled form (exactly DeepSeek-MLA's solution,
which DESIGN.md argues is an instance of this decomposition): a small slice
of ``rope_dims`` per kv head is roped and cached verbatim alongside X, while
the remaining (content) dims are position-free and decomposed. For
absolute-position architectures (musicgen-large, opt-6.7b) rope_dims == 0 and
the decomposition is EXACT vs dense attention (property-tested).

GQA generalizes trivially: q heads group onto kv-head weight slices.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.kv_cache import length_mask
from repro.distributed.sharding import constrain

NEG_INF = -1e30


def _group(h: int, kv: int) -> int:
    assert h % kv == 0, (h, kv)
    return h // kv


def decomposed_query_transform(q_nope: jax.Array, w_k_nope: jax.Array) -> jax.Array:
    """Hoist W_K into the query: R = Q W_K^T (the paper's first cascaded MatMul).

    q_nope:   (B, T, H, Dn)  content (un-roped) query dims
    w_k_nope: (Dm, KV, Dn)   content slice of the K projection
    returns   (B, T, H, Dm)
    """
    B, T, H, Dn = q_nope.shape
    Dm, KV, _ = w_k_nope.shape
    g = _group(H, KV)
    qg = q_nope.reshape(B, T, KV, g, Dn)
    r = jnp.einsum("btkgd,mkd->btkgm", qg, w_k_nope)
    return r.reshape(B, T, H, Dm)


def decomposed_scores(r: jax.Array, x_cache: jax.Array) -> jax.Array:
    """Second cascaded MatMul: scores = R X^T.

    r: (B, T, H, Dm), x_cache: (B, N, Dm) -> (B, T, H, N)."""
    return jnp.einsum("bthm,bnm->bthn", r, x_cache)


def decomposed_values(s: jax.Array, x_cache: jax.Array, w_v: jax.Array) -> jax.Array:
    """Value stage: out = (S X) W_V.

    s: (B, T, H, N) attention weights, x_cache: (B, N, Dm),
    w_v: (Dm, KV, Dh) -> (B, T, H, Dh)."""
    B, T, H, N = s.shape
    Dm, KV, Dh = w_v.shape
    g = _group(H, KV)
    p = jnp.einsum("bthn,bnm->bthm", s, x_cache)  # P = S X
    pg = p.reshape(B, T, KV, g, Dm)
    out = jnp.einsum("btkgm,mkd->btkgd", pg, w_v)
    return out.reshape(B, T, H, Dh)


def decomposed_attention(
    q_nope: jax.Array,      # (B, T, H, Dn) content query (post q-rope removal)
    q_rope: jax.Array,      # (B, T, H, R) roped query slice (R may be 0)
    x_cache: jax.Array,     # (B, N, Dm)
    k_rope: jax.Array,      # (B, N, KV, R) roped key slice
    w_k_nope: jax.Array,    # (Dm, KV, Dn)
    w_v: jax.Array,         # (Dm, KV, Dh)
    length: jax.Array,      # () or (B,) int32 valid tokens
    scale: float,
    query_positions: jax.Array | None = None,  # (T,) absolute positions for causal mask
) -> jax.Array:
    """Full T1 attention over an X-cache. Returns (B, T, H, Dh)."""
    B, T, H, _ = q_nope.shape
    N = x_cache.shape[1]
    KV = w_v.shape[1]
    g = _group(H, KV)

    r = decomposed_query_transform(q_nope, w_k_nope)
    # R's Dm dim must match the X-cache sharding (model axis) — without this
    # the SPMD partitioner all-gathers the whole X cache in f32 (measured
    # 103 GB/device on musicgen decode_32k; EXPERIMENTS.md §Perf cell A)
    r = constrain(r, "act_batch", None, None, "act_mlp")
    s = decomposed_scores(r, x_cache)  # content scores (B,T,H,N)
    if q_rope.shape[-1] > 0:
        # rope keys may be per-kv-head (KV_r == KV) or shared (KV_r == 1, MLA)
        kv_r = k_rope.shape[2]
        g_r = _group(H, kv_r)
        qg = q_rope.reshape(B, T, kv_r, g_r, q_rope.shape[-1])
        s_rope = jnp.einsum("btkgr,bnkr->btkgn", qg, k_rope).reshape(B, T, H, N)
        s = s + s_rope
    s = s.astype(jnp.float32) * scale

    pos_j = jnp.arange(N, dtype=jnp.int32)
    # (B|1, 1, N): written slots — length is () or per-row (B,) (paged serving)
    ok = length_mask(length, N)[:, None, :]
    if query_positions is not None:
        ok = ok & (pos_j[None, :] <= query_positions[:, None])[None]  # (T, N) causal
    s = jnp.where(ok[:, :, None, :], s, NEG_INF)

    w = jax.nn.softmax(s, axis=-1).astype(x_cache.dtype)
    return decomposed_values(w, x_cache, w_v)
