"""T2 — Cascade Pruning-Quantization (CPQ) of the KV / X cache, with the
Hierarchical Quantization Extension (HQE) for decode (paper §IV).

Cascade order (Fig. 4): (1) fine-grained per-channel magnitude pruning of
unimportant elements — applied at prefill AND decode — then (2) per-channel
quantization (PCQ) of the surviving non-zero elements. Code 0 is reserved for
pruned elements so they dequantize to exactly 0 and the non-zero payload can
be moved/compacted separately (the paper transfers only non-zero data; on TPU
the analogue is the reduced HBM byte count measured by the traffic model and
realized by the fused dequant-attention kernel reading int codes).

HQE (Fig. 5): per-(channel, level) scale/zero pairs. Level-0 parameters are
fit at prefill. During decode each new token is checked against the tolerance
range (TR) of the current level; if any channel falls outside, a NEW level is
created whose range is the union of the previous range and the token (the TR
"progressively extends"), so every token is quantized exactly once and no
channel is ever re-quantized. Levels saturate at ``max_levels`` (further
out-of-range tokens clip into the last level — the clip error is measurable
via ``cpq_dequant``).

All functions are jit-safe with static shapes: caches are pre-allocated to
``n_max`` tokens and ``max_levels`` levels.

Layout convention: ``x`` is (B, N, H, D) — tokens on axis 1; a "channel" is
an (H, D) pair, matching per-channel KV quantization literature (KIVI,
KVQuant): statistics are taken over the token axis.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import CPQCfg


class CPQTensor(NamedTuple):
    """A CPQ-compressed (B, N, H, D) cache tensor."""

    codes: jax.Array        # (B, N, H, D) int8 = code - 128, code in [0, 2^bits-1]; code 0 == pruned
    scale: jax.Array        # (B, L, H, D) f32, per (level, channel)
    zero: jax.Array         # (B, L, H, D) f32 — the range minimum ("zero point")
    level: jax.Array        # (B, N, H) int32 — HQE level of each token
    num_levels: jax.Array   # (B, H) int32 — levels allocated so far (>= 1)
    prune_thr: jax.Array    # (B, H, D) f32 — per-channel magnitude threshold

    @property
    def n_max(self) -> int:
        return self.codes.shape[1]


def _nonzero_codes(bits: int) -> int:
    # codes 1 .. 2^bits - 1 encode surviving values; code 0 == pruned
    return (1 << bits) - 1


def cpq_prune_mask(x: jax.Array, thr: jax.Array) -> jax.Array:
    """Element mask: keep |x| >= per-channel threshold. x: (..., N, H, D),
    thr broadcastable (..., 1, H, D)."""
    return jnp.abs(x) >= thr


def _fit_level(x: jax.Array, mask: jax.Array, bits: int):
    """Per-channel (over token axis 1) range fit of the surviving elements.

    Returns (scale, zero) with shapes (B, H, D)."""
    big = jnp.asarray(jnp.finfo(jnp.float32).max, jnp.float32)
    xf = x.astype(jnp.float32)
    lo = jnp.min(jnp.where(mask, xf, big), axis=1)
    hi = jnp.max(jnp.where(mask, xf, -big), axis=1)
    any_kept = jnp.any(mask, axis=1)
    lo = jnp.where(any_kept, lo, 0.0)
    hi = jnp.where(any_kept, hi, 0.0)
    steps = _nonzero_codes(bits) - 1  # codes 1..2^b-1 => 2^b-2 intervals
    scale = (hi - lo) / jnp.maximum(steps, 1)
    scale = jnp.maximum(scale, 1e-8)
    return scale, lo


def _encode(x: jax.Array, mask: jax.Array, scale: jax.Array, zero: jax.Array, bits: int):
    """Quantize surviving elements to codes 1..2^b-1 (code 0 == pruned).

    scale/zero broadcast against x: (B, 1 or N, H, D)."""
    xf = x.astype(jnp.float32)
    q = jnp.round((xf - zero) / scale) + 1.0
    q = jnp.clip(q, 1, _nonzero_codes(bits))
    # stored with a -128 bias so the full 8-bit code range fits in int8
    return (jnp.where(mask, q, 0.0) - 128.0).astype(jnp.int8)


def decode_codes(codes: jax.Array, scale: jax.Array, zero: jax.Array, dtype=jnp.bfloat16):
    """Dequantize: code 0 -> exactly 0; code c>0 -> (c-1)*scale + zero.
    Stored codes carry a -128 bias (int8 range)."""
    c = codes.astype(jnp.float32) + 128.0
    val = (c - 1.0) * scale + zero
    return jnp.where(c == 0, 0.0, val).astype(dtype)


# --------------------------------------------------------------- prefill path


def cpq_compress_prefill(x: jax.Array, cfg: CPQCfg, n_max: int) -> CPQTensor:
    """Bulk-compress prefill tokens (level 0) and allocate the decode arena.

    x: (B, N, H, D) with N <= n_max valid tokens (all treated valid here;
    masking of unwritten slots is the cache's job).
    """
    B, N, H, D = x.shape
    assert N <= n_max, (N, n_max)
    xf = jnp.abs(x.astype(jnp.float32))
    # per-channel magnitude threshold at the prune_ratio quantile over tokens
    thr = jnp.quantile(xf, cfg.prune_ratio, axis=1)  # (B, H, D)
    mask = cpq_prune_mask(x, thr[:, None])
    scale0, zero0 = _fit_level(x, mask, cfg.bits)  # (B, H, D)
    codes = _encode(x, mask, scale0[:, None], zero0[:, None], cfg.bits)

    L = cfg.max_levels
    scale = jnp.zeros((B, L, H, D), jnp.float32).at[:, 0].set(scale0)
    zero = jnp.zeros((B, L, H, D), jnp.float32).at[:, 0].set(zero0)
    if n_max > N:
        pad = jnp.zeros((B, n_max - N, H, D), jnp.int8)
        codes = jnp.concatenate([codes, pad], axis=1)
    level = jnp.zeros((B, n_max, H), jnp.int32)
    num_levels = jnp.ones((B, H), jnp.int32)
    return CPQTensor(codes, scale, zero, level, num_levels, thr)


# ---------------------------------------------------------------- decode path


def cpq_encode_token(scale: jax.Array, zero: jax.Array, num_levels: jax.Array,
                     prune_thr: jax.Array, x_t: jax.Array, cfg: CPQCfg):
    """HQE-encode one decode token per row WITHOUT touching the code arena.

    The per-row HQE math shared by the contiguous append (below) and the
    paged-arena append (serving/paged_cache.py), which scatter the returned
    code through different layouts. Inputs are the per-sequence side state:
    scale/zero (B, L, H, D), num_levels (B, H), prune_thr (B, H, D);
    x_t: (B, 1, H, D).

    Each token is quantized exactly once: if, for a head, any channel of the
    (pruned) token falls outside the tolerance range of that head's current
    level, a new level is spawned whose range is the union of the old range
    and the token's values (range extension), and the token is encoded with
    the new parameters. Otherwise the current level is reused.

    Returns (code_t (B,1,H,D) int8, level_t (B,H) int32, scale', zero',
    num_levels').
    """
    B, one, H, D = x_t.shape
    assert one == 1
    bits = cfg.bits
    steps = _nonzero_codes(bits) - 1
    xf = x_t[:, 0].astype(jnp.float32)  # (B, H, D)

    # (1) prune with the prefill-fitted per-channel thresholds (decode-stage
    #     pruning, as the paper extends pruning beyond prefill)
    mask = jnp.abs(xf) >= prune_thr  # (B, H, D)

    cur = num_levels - 1  # (B, H) current level index
    take = lambda a: jnp.take_along_axis(a, cur[:, None, :, None], axis=1)[:, 0]  # noqa: E731
    s_cur = take(scale)  # (B, H, D)
    z_cur = take(zero)
    lo_cur, hi_cur = z_cur, z_cur + s_cur * steps

    # (2) tolerance-range check over surviving channels (per head)
    tol = cfg.tolerance
    width = jnp.maximum(hi_cur - lo_cur, 1e-8)
    lo_tr = lo_cur - (tol - 1.0) * width
    hi_tr = hi_cur + (tol - 1.0) * width
    outside = mask & ((xf < lo_tr) | (xf > hi_tr))
    spawn = jnp.any(outside, axis=-1)  # (B, H)
    can_spawn = num_levels < cfg.max_levels
    spawn = spawn & can_spawn

    # (3) new-level parameters: union of current range and the token
    lo_new = jnp.minimum(lo_cur, jnp.where(mask, xf, lo_cur))
    hi_new = jnp.maximum(hi_cur, jnp.where(mask, xf, hi_cur))
    s_new = jnp.maximum((hi_new - lo_new) / jnp.maximum(steps, 1), 1e-8)

    new_idx = jnp.where(spawn, num_levels, cur)  # (B, H)
    put = lambda arr, val: jnp.where(  # noqa: E731
        (jnp.arange(arr.shape[1], dtype=jnp.int32)[None, :, None, None]
         == new_idx[:, None, :, None]) & spawn[:, None, :, None],
        val[:, None],
        arr,
    )
    scale2 = put(scale, s_new)
    zero2 = put(zero, lo_new)

    s_use = jnp.where(spawn[..., None], s_new, s_cur)
    z_use = jnp.where(spawn[..., None], lo_new, z_cur)
    code_t = _encode(x_t, mask[:, None], s_use[:, None], z_use[:, None], bits)  # (B,1,H,D)
    num_levels2 = num_levels + spawn.astype(jnp.int32)
    return code_t, new_idx.astype(jnp.int32), scale2, zero2, num_levels2


def cpq_fit_chunk(x: jax.Array, valid: jax.Array, cfg: CPQCfg):
    """Level-0 fit over the first ``valid`` tokens of a prompt chunk (chunked
    paged-prefill admission: the FIRST chunk plays the role the whole prompt
    plays in ``cpq_compress_prefill``, with the chunk's jit padding excluded
    from every statistic).

    x: (B, C, H, D); valid: () int32 in [1, C]. Returns
    (codes (B,C,H,D) i8, level (B,C,H) i32, scale (B,L,H,D), zero, num_levels
    (B,H), prune_thr (B,H,D)) — codes/levels of padding positions are
    garbage; callers route them to the null page.
    """
    B, C, H, D = x.shape
    xf = x.astype(jnp.float32)
    ok = (jnp.arange(C, dtype=jnp.int32) < valid)[None, :, None, None]
    big = jnp.asarray(jnp.finfo(jnp.float32).max, jnp.float32)

    # masked per-channel magnitude quantile (linear interpolation over the
    # valid prefix — invalid slots sort to the end and are never indexed)
    xs = jnp.sort(jnp.where(ok, jnp.abs(xf), big), axis=1)
    pos = cfg.prune_ratio * (valid - 1).astype(jnp.float32)
    lo_i = jnp.clip(jnp.floor(pos).astype(jnp.int32), 0, C - 1)
    hi_i = jnp.clip(lo_i + 1, 0, C - 1)
    frac = pos - lo_i.astype(jnp.float32)
    q_lo = jax.lax.dynamic_index_in_dim(xs, lo_i, axis=1, keepdims=False)
    q_hi = jax.lax.dynamic_index_in_dim(xs, hi_i, axis=1, keepdims=False)
    q_hi = jnp.where(hi_i < valid, q_hi, q_lo)  # never interpolate into padding
    thr = q_lo * (1.0 - frac) + q_hi * frac                     # (B, H, D)

    mask = cpq_prune_mask(x, thr[:, None]) & ok
    scale0, zero0 = _fit_level(x, mask, cfg.bits)               # (B, H, D)
    codes = _encode(x, mask, scale0[:, None], zero0[:, None], cfg.bits)

    L = cfg.max_levels
    scale = jnp.zeros((B, L, H, D), jnp.float32).at[:, 0].set(scale0)
    zero = jnp.zeros((B, L, H, D), jnp.float32).at[:, 0].set(zero0)
    level = jnp.zeros((B, C, H), jnp.int32)
    num_levels = jnp.ones((B, H), jnp.int32)
    return codes, level, scale, zero, num_levels, thr


def cpq_encode_chunk(scale: jax.Array, zero: jax.Array, num_levels: jax.Array,
                     prune_thr: jax.Array, x: jax.Array, valid: jax.Array,
                     cfg: CPQCfg):
    """HQE-encode a continuation chunk token by token (a scan of
    ``cpq_encode_token``): every valid token is quantized exactly once with
    the side state as of its turn — identical semantics to decode-time
    appends, so chunked prefill and decode share one compression story.
    Padding tokens (index >= ``valid``) neither commit side-state updates nor
    spawn levels; their codes are garbage routed to the null page.

    x: (B, C, H, D); valid: () int32. Returns (codes (B,C,H,D) i8,
    level (B,C,H) i32, scale', zero', num_levels')."""
    B, C, H, D = x.shape

    def step(carry, inp):
        s, z, nl = carry
        x_t, i = inp                                 # x_t: (B, H, D)
        code_t, lvl_t, s2, z2, nl2 = cpq_encode_token(
            s, z, nl, prune_thr, x_t[:, None], cfg)
        upd = i < valid
        s, z, nl = jax.tree.map(
            lambda new, old: jnp.where(upd, new, old), (s2, z2, nl2), (s, z, nl))
        return (s, z, nl), (code_t[:, 0], lvl_t)

    (scale, zero, num_levels), (codes, level) = jax.lax.scan(
        step, (scale, zero, num_levels),
        (x.swapaxes(0, 1), jnp.arange(C, dtype=jnp.int32)))
    return (codes.swapaxes(0, 1), level.swapaxes(0, 1),
            scale, zero, num_levels)


def cpq_append_decode(t: CPQTensor, x_t: jax.Array, pos: jax.Array, cfg: CPQCfg) -> CPQTensor:
    """HQE append of one token to the contiguous arena. x_t: (B, 1, H, D);
    pos: () int32 write slot. See ``cpq_encode_token`` for the HQE math."""
    code_t, level_t, scale, zero, num_levels = cpq_encode_token(
        t.scale, t.zero, t.num_levels, t.prune_thr, x_t, cfg)
    codes = jax.lax.dynamic_update_slice_in_dim(t.codes, code_t, pos, axis=1)
    level = jax.lax.dynamic_update_slice_in_dim(t.level, level_t[:, None], pos, axis=1)
    return CPQTensor(codes, scale, zero, level, num_levels, t.prune_thr)


# ------------------------------------------------------------------ reference


def cpq_dequant(t: CPQTensor, dtype=jnp.bfloat16) -> jax.Array:
    """Reference dequantization of the whole arena -> (B, N, H, D)."""
    # gather per-token scale/zero via the level index
    lvl = t.level[..., None]  # (B, N, H, 1)
    s = jnp.take_along_axis(t.scale, jnp.broadcast_to(lvl, t.codes.shape), axis=1)
    z = jnp.take_along_axis(t.zero, jnp.broadcast_to(lvl, t.codes.shape), axis=1)
    return decode_codes(t.codes, s, z, dtype)


def cpq_roundtrip_error(x: jax.Array, t: CPQTensor) -> dict[str, jax.Array]:
    """Diagnostics: error of dequant(compress(x)) on the first N tokens."""
    n = x.shape[1]
    xh = cpq_dequant(t, jnp.float32)[:, :n]
    xf = x.astype(jnp.float32)
    kept = t.codes[:, :n] != -128  # stored code 0 - 128 == pruned
    err = jnp.abs(xh - xf)
    return {
        "max_err_kept": jnp.max(jnp.where(kept, err, 0.0)),
        "rms_err": jnp.sqrt(jnp.mean(err**2)),
        "keep_frac": jnp.mean(kept.astype(jnp.float32)),
        "pruned_exact_zero": jnp.max(jnp.where(~kept, jnp.abs(xh), 0.0)),
    }


# -------------------------------------------------------------- traffic model


def cpq_bytes_per_token(cfg: CPQCfg, h: int, d: int, keep_frac: float | None = None) -> float:
    """Effective off-chip bytes per cached token under CPQ ("transfer only
    the non-zero KV cache"): non-zero payload + 1-bit occupancy map + level
    byte per (token, head). Per-(level,channel) scale/zero are amortized and
    excluded (they are O(L*H*D) per sequence, not per token)."""
    keep = (1.0 - cfg.prune_ratio) if keep_frac is None else keep_frac
    payload = keep * h * d * cfg.bits / 8.0
    bitmap = h * d / 8.0
    level = h * 1.0
    return payload + bitmap + level


def dense_bytes_per_token(h: int, d: int, dtype_bytes: int = 2) -> float:
    return float(h * d * dtype_bytes)
