"""Attention-mode dispatcher.

One entry point per phase:
  * ``dense_attention``      — reference SDA over explicit K/V (train/prefill)
  * ``init_cache``           — build the decode arena for the configured mode
  * ``prefill_into_cache``   — bulk-write prompt K/V (mode-specific compress)
  * ``decode_attend``        — one-token attention over the cache + append

Prefill COMPUTE is always dense (the paper's techniques target the decode
traffic; CPQ compresses prefill *outputs* on the fly). The mode determines
what is cached and how decode reads it.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import AttentionRuntime
from repro.core import cpq as cpq_lib
from repro.core import kv_cache as kvc
from repro.core import retrieval_attention as ret_lib
from repro.core.decomposed_attention import decomposed_attention

NEG_INF = -1e30

length_mask = kvc.length_mask  # canonical (B|1, N) written-slot mask


# ------------------------------------------------------------------- dense


def dense_attention(
    q: jax.Array,              # (B, T, H, Dh)
    k: jax.Array,              # (B, S, KV, Dh)
    v: jax.Array,              # (B, S, KV, Dh)
    scale: float,
    causal: bool = True,
    q_offset: jax.Array | int = 0,   # absolute position of q[0] (decode)
    kv_length: Optional[jax.Array] = None,  # () or (B,) valid kv tokens (cache arenas)
    logit_bias: Optional[jax.Array] = None,
) -> jax.Array:
    """Reference GQA scaled dot-product attention (pure jnp oracle)."""
    B, T, H, Dh = q.shape
    S, KV = k.shape[1], k.shape[2]
    g = H // KV
    qg = q.reshape(B, T, KV, g, Dh)
    s = jnp.einsum("btkgd,bskd->btkgs", qg, k).astype(jnp.float32) * scale
    s = s.reshape(B, T, H, S)
    if logit_bias is not None:
        s = s + logit_bias

    pos_j = jnp.arange(S, dtype=jnp.int32)
    ok = jnp.ones((1, T, S), bool)
    if causal:
        pos_i = jnp.arange(T, dtype=jnp.int32) + q_offset
        ok = ok & (pos_j[None, :] <= pos_i[:, None])[None]
    if kv_length is not None:
        ok = ok & length_mask(kv_length, S)[:, None, :]
    s = jnp.where(ok[:, :, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    wg = w.reshape(B, T, KV, g, S).astype(v.dtype)
    # output head dim follows V (MLA has Dv != Dq)
    return jnp.einsum("btkgs,bskd->btkgd", wg, v).reshape(B, T, H, v.shape[-1])


def cpq_chunked_decode_attention(q, kt, vt, length, scale: float,
                                 chunk: int = 2048) -> jax.Array:
    """T2 decode attention with IN-LOOP dequantization (the jnp analogue of
    kernels/cpq_dequant_attn): a scan over cache chunks dequantizes int8
    codes transiently, so HBM moves the COMPRESSED bytes — dequantizing the
    whole arena first costs more traffic than a bf16 cache (measured:
    1.53e12 vs 1.49e12 B/device on musicgen decode_32k; EXPERIMENTS.md §Perf
    cell A iteration A3). Level lookup is a one-hot (chunk, L) matmul like
    the kernel's DQU. q: (B, 1, H, Dh) -> (B, 1, H, Dv)."""
    B, _, H, Dh = q.shape
    N, KV = kt.codes.shape[1], kt.codes.shape[2]
    Dv = vt.codes.shape[3]
    L = kt.scale.shape[1]
    g = H // KV
    c = min(chunk, N)
    pad = (-N) % c
    nch = (N + pad) // c

    def chunked(t, d):
        a = jnp.pad(t, ((0, 0), (0, pad)) + ((0, 0),) * (t.ndim - 2),
                    constant_values=d) if pad else t
        return a.reshape(B, nch, c, *a.shape[2:]).swapaxes(0, 1)

    qg = q[:, 0].reshape(B, KV, g, Dh).astype(jnp.float32)

    def dequant(codes, level, scale_t, zero_t):
        # codes: (B,c,KV,D); level: (B,c,KV); scale/zero: (B,L,KV,D)
        # bf16 output: the dequantized chunk is the traffic the plain-XLA
        # path cannot avoid (the Pallas kernel keeps it in VMEM) — halve it
        oh = jax.nn.one_hot(level, L, dtype=jnp.float32)       # (B,c,KV,L)
        s = jnp.einsum("bckl,blkd->bckd", oh, scale_t)
        z = jnp.einsum("bckl,blkd->bckd", oh, zero_t)
        cd = codes.astype(jnp.float32) + 128.0
        return jnp.where(cd == 0.0, 0.0, (cd - 1.0) * s + z).astype(jnp.bfloat16)

    def body(acc, inp):
        m, l, o = acc
        ck, cv, lvk, lvv, base = inp
        k_hat = dequant(ck, lvk, kt.scale, kt.zero)            # (B,c,KV,Dh)
        s = jnp.einsum("bkgd,bckd->bkgc", qg, k_hat) * scale
        pos = base + jnp.arange(c, dtype=jnp.int32)
        # length is () (contiguous arena) or (B,) (paged per-row lengths)
        msk = pos[None, :] < jnp.reshape(length, (-1, 1))      # (B|1, c)
        s = jnp.where(msk[:, None, None, :], s, NEG_INF)
        m2 = jnp.maximum(m, jnp.max(s, axis=-1))
        corr = jnp.exp(m - m2)
        p = jnp.exp(s - m2[..., None])
        l2 = l * corr + jnp.sum(p, axis=-1)
        v_hat = dequant(cv, lvv, vt.scale, vt.zero)
        o2 = o * corr[..., None] + jnp.einsum("bkgc,bckd->bkgd", p, v_hat)
        return (m2, l2, o2), None

    m0 = jnp.full((B, KV, g), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, KV, g), jnp.float32)
    o0 = jnp.zeros((B, KV, g, Dv), jnp.float32)
    bases = jnp.arange(nch, dtype=jnp.int32) * c
    (m, l, o), _ = jax.lax.scan(
        body, (m0, l0, o0),
        (chunked(kt.codes, -128), chunked(vt.codes, -128),
         chunked(kt.level, 0), chunked(vt.level, 0), bases))
    out = o / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(B, 1, H, Dv).astype(q.dtype)


def decomposed_cpq_chunked_decode(q_nope, q_rope, xt, k_rope, w_k_nope, w_v,
                                  length, scale: float, chunk: int = 2048):
    """T1+T2 composition: decode attention over a CPQ-COMPRESSED X cache.

    Per chunk: dequantize X codes (HQE one-hot level lookup), run BOTH
    cascaded MatMuls of the decomposition on the same dequantized tile
    (scores R X^T and values P += S X), online softmax across chunks. The
    per-token cache payload is d_model * bits/8 * keep_frac — T1's 2x (MHA)
    stacked with T2's ~4.5x. q_nope: (B,1,H,Dn) -> (B,1,H,Dv)."""
    from repro.core.decomposed_attention import decomposed_query_transform
    from repro.distributed.sharding import constrain

    B, _, H, Dn = q_nope.shape
    Dm = xt.codes.shape[3]
    KV, Dv = w_v.shape[1], w_v.shape[2]
    N = xt.codes.shape[1]
    L = xt.scale.shape[1]
    rr = 0 if q_rope is None else q_rope.shape[-1]

    r = decomposed_query_transform(q_nope, w_k_nope)[:, 0]  # (B, H, Dm)
    r = constrain(r, "act_batch", None, "act_mlp")
    qr = None if rr == 0 else q_rope[:, 0].astype(jnp.float32)  # (B, H, rr)

    c = min(chunk, N)
    pad = (-N) % c
    nch = (N + pad) // c

    def chunked(t, d=0):
        a = jnp.pad(t, ((0, 0), (0, pad)) + ((0, 0),) * (t.ndim - 2),
                    constant_values=d) if pad else t
        return a.reshape(B, nch, c, *a.shape[2:]).swapaxes(0, 1)

    def dequant(codes, level):
        oh = jax.nn.one_hot(level[:, :, 0], L, dtype=jnp.float32)  # (B,c,L)
        s = jnp.einsum("bcl,bld->bcd", oh, xt.scale[:, :, 0, :])
        z = jnp.einsum("bcl,bld->bcd", oh, xt.zero[:, :, 0, :])
        cd = codes[:, :, 0, :].astype(jnp.float32) + 128.0
        return jnp.where(cd == 0.0, 0.0, (cd - 1.0) * s + z).astype(jnp.bfloat16)

    def body(acc, inp):
        m, l, p_acc = acc
        codes_b, lvl_b, kr_b, base = inp
        x_hat = dequant(codes_b, lvl_b)                        # (B, c, Dm)
        s = jnp.einsum("bhm,bcm->bhc", r.astype(jnp.bfloat16),
                       x_hat).astype(jnp.float32)
        if qr is not None:
            kv_r = kr_b.shape[2]
            g_r = H // kv_r
            s = s + jnp.einsum(
                "bkgr,bckr->bkgc",
                qr.reshape(B, kv_r, g_r, rr), kr_b.astype(jnp.float32)
            ).reshape(B, H, c)
        s = s * scale
        pos = base + jnp.arange(c, dtype=jnp.int32)
        msk = pos[None, :] < jnp.reshape(length, (-1, 1))      # (B|1, c)
        s = jnp.where(msk[:, None, :], s, NEG_INF)
        m2 = jnp.maximum(m, jnp.max(s, axis=-1))
        corr = jnp.exp(m - m2)
        w = jnp.exp(s - m2[..., None])
        l2 = l * corr + jnp.sum(w, axis=-1)
        p2 = p_acc * corr[..., None] + jnp.einsum(
            "bhc,bcm->bhm", w, x_hat.astype(jnp.float32))
        return (m2, l2, p2), None

    m0 = jnp.full((B, H), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, H), jnp.float32)
    p0 = jnp.zeros((B, H, Dm), jnp.float32)
    bases = jnp.arange(nch, dtype=jnp.int32) * c
    (m, l, p), _ = jax.lax.scan(
        body, (m0, l0, p0),
        (chunked(xt.codes, -128), chunked(xt.level), chunked(k_rope), bases))
    p = (p / jnp.maximum(l, 1e-30)[..., None])                 # (B, H, Dm)
    g = H // KV
    out = jnp.einsum("bkgm,mkd->bkgd",
                     p.reshape(B, KV, g, Dm).astype(w_v.dtype), w_v)
    return out.reshape(B, 1, H, Dv)


# ----------------------------------------------------------------- caches


def init_cache(rt: AttentionRuntime, *, batch: int, n_max: int, kv: int, dh: int,
               d_model: int, rope_dims: int, dtype=jnp.bfloat16) -> kvc.Cache:
    if rt.mode == "dense":
        return kvc.init_dense(batch, n_max, kv, dh, dtype)
    if rt.mode == "decomposed":
        return kvc.init_x(batch, n_max, d_model, kv, rope_dims, dtype)
    if rt.mode == "cpq":
        return kvc.init_cpq(batch, n_max, kv, dh, rt.cpq)
    if rt.mode == "decomposed_cpq":
        return kvc.init_cpq_x(batch, n_max, d_model, kv, rope_dims, rt.cpq, dtype)
    if rt.mode == "retrieval":
        return kvc.init_retrieval(batch, n_max, kv, dh, rt.retrieval, dtype)
    raise ValueError(rt.mode)


def prefill_into_cache(
    rt: AttentionRuntime,
    cache: kvc.Cache,
    *,
    k: jax.Array,              # (B, S, KV, Dh) roped keys
    v: jax.Array,              # (B, S, KV, Dh)
    x: Optional[jax.Array],    # (B, S, Dm) attention-block input (T1 operand)
    k_rope: Optional[jax.Array],  # (B, S, KV, R) decoupled roped slice (T1)
    length: jax.Array,         # () number of prompt tokens
) -> kvc.Cache:
    S = k.shape[1]
    if isinstance(cache, kvc.DenseKVCache):
        return kvc.DenseKVCache(
            kvc.append_tokens(cache.k, k, 0), kvc.append_tokens(cache.v, v, 0), length)
    if isinstance(cache, kvc.XCache):
        return kvc.XCache(
            kvc.append_tokens(cache.x, x, 0),
            kvc.append_tokens(cache.k_rope, k_rope, 0) if k_rope is not None else cache.k_rope,
            length)
    if isinstance(cache, kvc.CPQKVCache):
        kt = cpq_lib.cpq_compress_prefill(k, rt.cpq, cache.k.n_max)
        vt = cpq_lib.cpq_compress_prefill(v, rt.cpq, cache.v.n_max)
        return kvc.CPQKVCache(kt, vt, length)
    if isinstance(cache, kvc.CPQXCache):  # T1+T2: compress the X operand
        xt = cpq_lib.cpq_compress_prefill(x[:, :, None, :], rt.cpq, cache.x.n_max)
        return kvc.CPQXCache(
            xt,
            kvc.append_tokens(cache.k_rope, k_rope, 0)
            if k_rope is not None else cache.k_rope,
            length)
    if isinstance(cache, kvc.RetrievalCache):
        dp = rt.retrieval.proxy_dim or k.shape[-1]
        codes, pscale, pzero = ret_lib.fit_proxy(k[..., :dp], rt.retrieval.proxy_bits)
        return kvc.RetrievalCache(
            kvc.append_tokens(cache.k, k, 0),
            kvc.append_tokens(cache.v, v, 0),
            kvc.append_tokens(cache.proxy, codes, 0),
            pscale, pzero, length)
    raise TypeError(type(cache))


# ------------------------------------------------------------------ decode


def decode_attend(
    rt: AttentionRuntime,
    cache: kvc.Cache,
    *,
    q: jax.Array,              # (B, 1, H, Dh) roped query
    k_t: jax.Array,            # (B, 1, KV, Dh) roped new key
    v_t: jax.Array,            # (B, 1, KV, Dh)
    x_t: Optional[jax.Array],  # (B, 1, Dm)
    k_rope_t: Optional[jax.Array],  # (B, 1, KV, R)
    q_nope: Optional[jax.Array],    # (B, 1, H, Dn) content query (T1)
    q_rope: Optional[jax.Array],    # (B, 1, H, R) roped query slice (T1)
    w_k_nope: Optional[jax.Array],  # (Dm, KV, Dn) (T1)
    w_v: Optional[jax.Array],       # (Dm, KV, Dh) (T1)
    scale: float,
) -> tuple[jax.Array, kvc.Cache]:
    """Append one token to the cache and attend over it. Returns
    (out (B,1,H,Dh), new_cache)."""
    pos = cache.length
    new_len = cache.length + 1

    if isinstance(cache, kvc.DenseKVCache):
        cache = kvc.DenseKVCache(
            kvc.append_tokens(cache.k, k_t, pos), kvc.append_tokens(cache.v, v_t, pos), new_len)
        out = dense_attention(q, cache.k, cache.v, scale, causal=False, kv_length=new_len)
        return out, cache

    if isinstance(cache, kvc.XCache):
        cache = kvc.XCache(
            kvc.append_tokens(cache.x, x_t, pos),
            kvc.append_tokens(cache.k_rope, k_rope_t, pos)
            if k_rope_t is not None else cache.k_rope,
            new_len)
        out = decomposed_attention(
            q_nope, q_rope, cache.x, cache.k_rope, w_k_nope, w_v, new_len, scale)
        return out, cache

    if isinstance(cache, kvc.CPQKVCache):
        kt = cpq_lib.cpq_append_decode(cache.k, k_t, pos, rt.cpq)
        vt = cpq_lib.cpq_append_decode(cache.v, v_t, pos, rt.cpq)
        cache = kvc.CPQKVCache(kt, vt, new_len)
        out = cpq_chunked_decode_attention(q, kt, vt, new_len, scale)
        return out, cache

    if isinstance(cache, kvc.CPQXCache):
        # T1+T2: HQE-append the new X row, then the fused two-stage sweep
        # over dequantized X chunks (scores AND value stage reuse each chunk)
        xt = cpq_lib.cpq_append_decode(cache.x, x_t[:, :, None, :], pos, rt.cpq)
        cache = kvc.CPQXCache(
            xt,
            kvc.append_tokens(cache.k_rope, k_rope_t, pos)
            if k_rope_t is not None else cache.k_rope,
            new_len)
        out = decomposed_cpq_chunked_decode(
            q_nope, q_rope, xt, cache.k_rope, w_k_nope, w_v, new_len, scale)
        return out, cache

    if isinstance(cache, kvc.RetrievalCache):
        dp = rt.retrieval.proxy_dim or k_t.shape[-1]
        code_t = ret_lib.encode_proxy(
            k_t[..., :dp], cache.proxy_scale, cache.proxy_zero, rt.retrieval.proxy_bits)
        cache = kvc.RetrievalCache(
            kvc.append_tokens(cache.k, k_t, pos),
            kvc.append_tokens(cache.v, v_t, pos),
            kvc.append_tokens(cache.proxy, code_t, pos),
            cache.proxy_scale, cache.proxy_zero, new_len)
        out = ret_lib.retrieval_attention(
            q, cache.k, cache.v, cache.proxy, cache.proxy_scale, cache.proxy_zero,
            new_len, rt.retrieval, scale)
        return out, cache

    raise TypeError(type(cache))
