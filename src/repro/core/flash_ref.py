"""Memory-efficient (flash) attention in pure jnp with a CUSTOM VJP.

Forward: online-softmax scan over KV chunks (saves out + logsumexp, never the
(T x S) score matrix). Backward: flash-attention backward — recompute scores
chunk-by-chunk from (q, k, v, out, lse); dq rides the scan carry, dk/dv are
emitted per chunk. Without the custom VJP, autodiff through the scan saves
every per-chunk softmax carry and memory explodes (measured 37GB/device for
one layer of jamba train_4k — see EXPERIMENTS.md §Perf).

This is the oracle (ref.py) for the Pallas flash kernel, and the production
path for train/prefill on long sequences.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain

NEG_INF = -1e30


def _pin_tiles(qs, ks, vs):
    """Pin the chunked q/k/v scan inputs: heads sharded where divisible,
    REPLICATED otherwise. Without this the partitioner propagates the
    (model-sharded) projection output through the tile reshape as a
    head_dim-contracted layout and ALL-REDUCES every score tile (measured
    6.6 TB/device on phi4 prefill_32k, whose 24 heads don't divide the
    16-way model axis — EXPERIMENTS.md §Perf cell B)."""
    qs = constrain(qs, None, "act_batch", None, "act_heads", None)
    ks = constrain(ks, None, "act_batch", None, "act_kv", None)
    vs = constrain(vs, None, "act_batch", None, "act_kv", None)
    return qs, ks, vs


def _chunk_kv(x, kc):
    B, S = x.shape[:2]
    return x.reshape(B, S // kc, kc, *x.shape[2:]).swapaxes(0, 1)


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def flash_attention(q, k, v, scale, causal=True, q_offset=0, kv_chunk=1024):
    out, _ = _flash_fwd_impl(q, k, v, scale, causal, q_offset, kv_chunk)
    return out


def _flash_fwd_impl(q, k, v, scale, causal, q_offset, kv_chunk, q_chunk=512):
    """Two-level flash: outer scan over Q chunks, inner scan over KV chunks.

    The online-softmax state is per-Q-CHUNK ((B, qc, ...) instead of
    (B, T, ...)): carrying full-T state through the KV scan costs
    nk * T * Dv * 4B of HBM traffic PER LAYER (measured as the dominant
    memory-roofline term across every train/prefill cell — EXPERIMENTS.md
    §Perf iteration 1); Q-chunking cuts it to the tile working set, exactly
    like the Pallas kernel's VMEM accumulator."""
    B, T, H, Dh = q.shape
    S, KV = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    g = H // KV
    kc = min(kv_chunk, S)
    qc = min(q_chunk, T)
    pad_k = (-S) % kc
    pad_q = (-T) % qc
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    ks, vs = _chunk_kv(k, kc), _chunk_kv(v, kc)
    kpos = jnp.arange(S + pad_k, dtype=jnp.int32).reshape(-1, kc)
    qs = q.reshape(B, (T + pad_q) // qc, qc, H, Dh).swapaxes(0, 1)
    qs, ks, vs = _pin_tiles(qs, ks, vs)
    qpos_all = (jnp.arange(T + pad_q, dtype=jnp.int32) + q_offset).reshape(-1, qc)

    def q_block(_, q_inp):
        qb, qp = q_inp                       # (B, qc, H, Dh), (qc,)
        qg = qb.reshape(B, qc, KV, g, Dh)

        def body(acc, inp):
            m, l, o = acc
            kb, vb, kp = inp
            s = jnp.einsum("btkgd,bskd->btkgs", qg, kb).astype(jnp.float32) * scale
            mask = kp[None, :] < S
            if causal:
                mask = mask & (kp[None, :] <= qp[:, None])
            else:
                mask = jnp.broadcast_to(mask, (qc, kc))
            s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
            m2 = jnp.maximum(m, jnp.max(s, axis=-1))
            corr = jnp.exp(m - m2)
            p = jnp.exp(s - m2[..., None])
            l2 = l * corr + jnp.sum(p, axis=-1)
            o2 = o * corr[..., None] + jnp.einsum(
                "btkgs,bskd->btkgd", p.astype(vb.dtype), vb).astype(jnp.float32)
            return (m2, l2, o2), None

        m0 = jnp.full((B, qc, KV, g), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, qc, KV, g), jnp.float32)
        o0 = jnp.zeros((B, qc, KV, g, Dv), jnp.float32)
        (m, l, o), _ = jax.lax.scan(body, (m0, l0, o0), (ks, vs, kpos))
        out = (o / jnp.maximum(l, 1e-30)[..., None]).reshape(B, qc, H, Dv)
        lse = (m + jnp.log(jnp.maximum(l, 1e-30))).reshape(B, qc, H)
        return (), (out.astype(q.dtype), lse)

    _, (outs, lses) = jax.lax.scan(q_block, (), (qs, qpos_all))
    out = outs.swapaxes(0, 1).reshape(B, T + pad_q, H, Dv)[:, :T]
    lse = lses.swapaxes(0, 1).reshape(B, T + pad_q, H)[:, :T]
    return out, lse


def _flash_fwd(q, k, v, scale, causal, q_offset, kv_chunk):
    out, lse = _flash_fwd_impl(q, k, v, scale, causal, q_offset, kv_chunk)
    return out, (q, k, v, out, lse)


def _flash_bwd(scale, causal, q_offset, kv_chunk, res, dout, q_chunk=512):
    """Two-pass tiled flash backward (the standard schedule):
      pass 1 (KV-outer): recompute per-tile scores, accumulate (dk, dv) per
              KV chunk — inner Q scan carries only the (B, kc, ...) tile;
      pass 2 (Q-outer):  dq per Q chunk — inner KV scan carries (B, qc, ...).
    No full-(T|S) f32 state ever rides a scan carry (the bytes-roofline fix,
    EXPERIMENTS.md §Perf)."""
    q, k, v, out, lse = res
    B, T, H, Dh = q.shape
    S, KV = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    g = H // KV
    kc = min(kv_chunk, S)
    qc = min(q_chunk, T)
    pad_k = (-S) % kc
    pad_q = (-T) % qc
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    ks, vs = _chunk_kv(k, kc), _chunk_kv(v, kc)
    ks = constrain(ks, None, "act_batch", None, "act_kv", None)
    vs = constrain(vs, None, "act_batch", None, "act_kv", None)
    kpos = jnp.arange(S + pad_k, dtype=jnp.int32).reshape(-1, kc)

    dog = dout.reshape(B, T, KV, g, Dv).astype(jnp.float32)
    og = out.reshape(B, T, KV, g, Dv).astype(jnp.float32)
    D = jnp.sum(dog * og, axis=-1)  # (B, T, KV, g)

    def padq(a):
        return jnp.pad(a, [(0, 0), (0, pad_q)] + [(0, 0)] * (a.ndim - 2)) \
            if pad_q else a

    nq = (T + pad_q) // qc
    qs = padq(q).reshape(B, nq, qc, KV, g, Dh).swapaxes(0, 1)
    dos = padq(dout.reshape(B, T, KV, g, Dv).astype(jnp.float32)
               ).reshape(B, nq, qc, KV, g, Dv).swapaxes(0, 1)
    lses = padq(lse.reshape(B, T, KV, g) + 0.0).reshape(B, nq, qc, KV, g).swapaxes(0, 1)
    Ds = padq(D).reshape(B, nq, qc, KV, g).swapaxes(0, 1)
    qpos = (jnp.arange(T + pad_q, dtype=jnp.int32) + q_offset).reshape(nq, qc)
    qvalid = (jnp.arange(T + pad_q, dtype=jnp.int32) < T).reshape(nq, qc)

    def _tile(qb, dob, lseb, Db, qp, qv, kb, vb, kp):
        """Shared per-(q-tile, kv-tile) math. Returns (p, ds)."""
        s = jnp.einsum("btkgd,bskd->btkgs", qb, kb).astype(jnp.float32) * scale
        mask = (kp[None, :] < S) & qv[:, None]
        if causal:
            mask = mask & (kp[None, :] <= qp[:, None])
        s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
        p = jnp.exp(s - lseb[..., None])
        dp = jnp.einsum("btkgd,bskd->btkgs", dob, vb.astype(jnp.float32))
        ds = p * (dp - Db[..., None]) * scale
        return p, ds

    # ---- pass 1: dk, dv (KV-outer)
    def kv_outer(_, kv_inp):
        kb, vb, kp = kv_inp

        def q_inner(acc, q_inp):
            dk_c, dv_c = acc
            qb, dob, lseb, Db, qp, qv = q_inp
            p, ds = _tile(qb, dob, lseb, Db, qp, qv, kb, vb, kp)
            dk_c = dk_c + jnp.einsum("btkgs,btkgd->bskd", ds, qb.astype(jnp.float32))
            dv_c = dv_c + jnp.einsum("btkgs,btkgd->bskd", p, dob)
            return (dk_c, dv_c), None

        z = (jnp.zeros((B, kc, KV, Dh), jnp.float32),
             jnp.zeros((B, kc, KV, Dv), jnp.float32))
        (dk_c, dv_c), _ = jax.lax.scan(q_inner, z, (qs, dos, lses, Ds, qpos, qvalid))
        return (), (dk_c, dv_c)

    _, (dks, dvs) = jax.lax.scan(kv_outer, (), (ks, vs, kpos))
    dk = dks.swapaxes(0, 1).reshape(B, S + pad_k, KV, Dh)[:, :S]
    dv = dvs.swapaxes(0, 1).reshape(B, S + pad_k, KV, Dv)[:, :S]

    # ---- pass 2: dq (Q-outer)
    def q_outer(_, q_inp):
        qb, dob, lseb, Db, qp, qv = q_inp

        def kv_inner(dq_c, kv_inp):
            kb, vb, kp = kv_inp
            _, ds = _tile(qb, dob, lseb, Db, qp, qv, kb, vb, kp)
            return dq_c + jnp.einsum("btkgs,bskd->btkgd", ds,
                                     kb.astype(jnp.float32)), None

        dq0 = jnp.zeros((B, qc, KV, g, Dh), jnp.float32)
        dq_c, _ = jax.lax.scan(kv_inner, dq0, (ks, vs, kpos))
        return (), dq_c

    _, dqs = jax.lax.scan(q_outer, (), (qs, dos, lses, Ds, qpos, qvalid))
    dq = dqs.swapaxes(0, 1).reshape(B, T + pad_q, KV, g, Dh)[:, :T]
    return (dq.reshape(B, T, H, Dh).astype(q.dtype), dk.astype(k.dtype),
            dv.astype(v.dtype))


flash_attention.defvjp(_flash_fwd, _flash_bwd)


def flash_attention_ref(q, k, v, scale, causal=True, q_chunk=512, kv_chunk=1024,
                        q_offset=0):
    """Back-compat wrapper (q_chunk kept for API stability; unused)."""
    return flash_attention(q, k, v, scale, causal, q_offset, kv_chunk)


def attention_auto(q, k, v, scale, causal=True, q_offset=0, kv_length=None,
                   flash_threshold: int = 1024):
    """Dispatch: exact dense oracle for small shapes, flash beyond."""
    from repro.core.attention import dense_attention

    T, S = q.shape[1], k.shape[1]
    if kv_length is not None or max(T, S) <= flash_threshold:
        return dense_attention(q, k, v, scale, causal=causal, q_offset=q_offset,
                               kv_length=kv_length)
    return flash_attention(q, k, v, scale, causal, q_offset)
